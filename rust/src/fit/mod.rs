//! Bounded nonlinear least squares.
//!
//! The paper fits Alg. 1's ten relaxation parameters with scipy's
//! Trust-Region-Reflective `least_squares`. scipy is not on the Rust side,
//! so we implement a bounded Levenberg–Marquardt optimizer:
//!
//! - parameters are affinely rescaled to the unit box [0,1]^n (the physical
//!   parameters span 6+ orders of magnitude, which would wreck the normal
//!   equations' conditioning),
//! - the LM step solves `(JᵀJ + μ·diag(JᵀJ))·δ = −Jᵀr` with adaptive μ,
//! - steps are projected back into the box (projection replaces TRR's
//!   reflection; both enforce feasibility — optimizer choice, not a paper
//!   claim),
//! - multi-start over seeded random initial points guards against local
//!   minima (the speedup surface is mildly non-convex in λ and s̄).

pub mod linalg;

use crate::perfmodel::{Measurement, ParamBounds, PerfModel, PerfParams, N_PARAMS};
use crate::util::rng::Rng;
use linalg::{norm, solve_symmetric, Mat};

/// Options for the LM optimizer.
#[derive(Debug, Clone)]
pub struct LmOptions {
    pub max_iters: usize,
    /// Stop when the relative cost improvement falls below this.
    pub ftol: f64,
    /// Stop when the scaled step norm falls below this.
    pub xtol: f64,
    /// Forward-difference step in scaled coordinates.
    pub fd_step: f64,
}

impl Default for LmOptions {
    fn default() -> Self {
        LmOptions {
            max_iters: 200,
            ftol: 1e-12,
            xtol: 1e-12,
            fd_step: 1e-7,
        }
    }
}

/// Outcome of a least-squares run.
#[derive(Debug, Clone)]
pub struct FitResult {
    /// Solution in physical coordinates.
    pub x: Vec<f64>,
    /// Final cost: ½·Σ r².
    pub cost: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Minimize ½‖r(x)‖² subject to lo ≤ x ≤ hi, starting from `x0`.
/// `residuals` must return the same-length vector on every call.
pub fn lm_bounded<F>(
    residuals: F,
    x0: &[f64],
    lo: &[f64],
    hi: &[f64],
    opts: &LmOptions,
) -> FitResult
where
    F: Fn(&[f64]) -> Vec<f64>,
{
    let n = x0.len();
    assert_eq!(lo.len(), n);
    assert_eq!(hi.len(), n);
    for i in 0..n {
        assert!(lo[i] < hi[i], "degenerate bound {i}");
    }

    // Scaled coordinates z ∈ [0,1]: x = lo + z·(hi−lo).
    let to_x = |z: &[f64]| -> Vec<f64> {
        (0..n).map(|i| lo[i] + z[i] * (hi[i] - lo[i])).collect()
    };
    let clamp01 = |z: &mut [f64]| {
        for v in z.iter_mut() {
            *v = v.clamp(0.0, 1.0);
        }
    };
    let eval = |z: &[f64]| -> (Vec<f64>, f64) {
        let r = residuals(&to_x(z));
        let cost = 0.5 * r.iter().map(|v| v * v).sum::<f64>();
        (r, cost)
    };

    let mut z: Vec<f64> = (0..n)
        .map(|i| ((x0[i] - lo[i]) / (hi[i] - lo[i])).clamp(0.0, 1.0))
        .collect();
    let (mut r, mut cost) = eval(&z);
    let m = r.len();
    let mut mu = 1e-3;
    let mut iterations = 0;
    let mut converged = false;

    for _ in 0..opts.max_iters {
        iterations += 1;
        // Forward-difference Jacobian in scaled space, stepping inward at
        // the upper boundary so evaluations stay feasible.
        let mut jac = Mat::zeros(m, n);
        for j in 0..n {
            let h = if z[j] + opts.fd_step <= 1.0 {
                opts.fd_step
            } else {
                -opts.fd_step
            };
            let mut zj = z.clone();
            zj[j] += h;
            let (rj, _) = eval(&zj);
            for i in 0..m {
                jac.set(i, j, (rj[i] - r[i]) / h);
            }
        }
        let jtj = jac.gram();
        let jtr = jac.t_mul_vec(&r);
        if norm(&jtr) < 1e-14 {
            converged = true;
            break;
        }

        // Try LM steps with increasing damping until the cost improves.
        let mut improved = false;
        for _ in 0..30 {
            let mut a = jtj.clone();
            for i in 0..n {
                let d = a.get(i, i);
                a.set(i, i, d + mu * d.max(1e-12));
            }
            let neg_jtr: Vec<f64> = jtr.iter().map(|v| -v).collect();
            if let Some(delta) = solve_symmetric(&a, &neg_jtr) {
                let mut z_new: Vec<f64> = z.iter().zip(&delta).map(|(a, b)| a + b).collect();
                clamp01(&mut z_new);
                let step: Vec<f64> = z_new.iter().zip(&z).map(|(a, b)| a - b).collect();
                if norm(&step) < opts.xtol {
                    converged = true;
                    break;
                }
                let (r_new, cost_new) = eval(&z_new);
                if cost_new.is_finite() && cost_new < cost {
                    let rel = (cost - cost_new) / cost.max(1e-300);
                    z = z_new;
                    r = r_new;
                    cost = cost_new;
                    mu = (mu * 0.33).max(1e-12);
                    improved = true;
                    if rel < opts.ftol {
                        converged = true;
                    }
                    break;
                }
            }
            mu *= 4.0;
            if mu > 1e12 {
                break;
            }
        }
        if converged || !improved {
            if !improved {
                converged = true; // stalled at a (local) optimum
            }
            break;
        }
    }

    FitResult {
        x: to_x(&z),
        cost,
        iterations,
        converged,
    }
}

/// Multi-start wrapper: run LM from the box midpoint plus `extra_starts`
/// random interior points, return the best result.
pub fn lm_multistart<F>(
    residuals: F,
    lo: &[f64],
    hi: &[f64],
    extra_starts: usize,
    seed: u64,
    opts: &LmOptions,
) -> FitResult
where
    F: Fn(&[f64]) -> Vec<f64>,
{
    let n = lo.len();
    let mut rng = Rng::seeded(seed);
    let mut starts: Vec<Vec<f64>> = Vec::new();
    let mid: Vec<f64> = (0..n).map(|i| 0.5 * (lo[i] + hi[i])).collect();
    starts.push(mid);
    for _ in 0..extra_starts {
        starts.push(
            (0..n)
                .map(|i| lo[i] + (hi[i] - lo[i]) * rng.uniform(0.05, 0.95))
                .collect(),
        );
    }
    let mut best: Option<FitResult> = None;
    for s in &starts {
        let res = lm_bounded(&residuals, s, lo, hi, opts);
        if best.as_ref().map_or(true, |b| res.cost < b.cost) {
            best = Some(res);
        }
    }
    best.unwrap()
}

/// The Alg. 1 fitting entry point: fit the 10 perf-model parameters to a
/// set of speedup measurements. Returns the fitted parameters and the MSE
/// over the *fitting* set.
pub fn fit_perfmodel(
    model: &PerfModel,
    measurements: &[Measurement],
    bounds: &ParamBounds,
    seed: u64,
) -> (PerfParams, f64) {
    assert!(
        measurements.len() >= N_PARAMS,
        "need >= {N_PARAMS} measurements to determine {N_PARAMS} parameters (got {})",
        measurements.len()
    );
    let residuals = |x: &[f64]| {
        let p = PerfParams::from_slice(x);
        model.residuals(&p, measurements)
    };
    // Start count balances robustness vs. fitting time; the paper reports
    // ~0.1 s fits, ours stay in the same ballpark at 7 starts. If the fit
    // looks stuck in a poor local minimum (MSE large relative to the
    // speedup scale), escalate with more random starts.
    let opts = LmOptions::default();
    let mut res = lm_multistart(&residuals, &bounds.lo, &bounds.hi, 6, seed, &opts);
    let scale: f64 = measurements.iter().map(|m| m.speedup * m.speedup).sum::<f64>()
        / measurements.len() as f64;
    if 2.0 * res.cost / measurements.len() as f64 > 5e-3 * scale {
        let retry = lm_multistart(&residuals, &bounds.lo, &bounds.hi, 18, seed ^ 0x5eed, &opts);
        if retry.cost < res.cost {
            res = retry;
        }
    }
    let p = PerfParams::from_slice(&res.x);
    let mse = model.mse(&p, measurements);
    (p, mse)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_exponential_decay() {
        // y = a·exp(−b·t) + c with a=5, b=0.7, c=1.
        let ts: Vec<f64> = (0..40).map(|i| i as f64 * 0.25).collect();
        let ys: Vec<f64> = ts.iter().map(|t| 5.0 * (-0.7 * t).exp() + 1.0).collect();
        let res = lm_bounded(
            |x| {
                ts.iter()
                    .zip(&ys)
                    .map(|(t, y)| x[0] * (-x[1] * t).exp() + x[2] - y)
                    .collect()
            },
            &[1.0, 0.1, 0.0],
            &[0.0, 0.0, -10.0],
            &[50.0, 10.0, 10.0],
            &LmOptions::default(),
        );
        assert!(res.cost < 1e-12, "cost={}", res.cost);
        assert!((res.x[0] - 5.0).abs() < 1e-4);
        assert!((res.x[1] - 0.7).abs() < 1e-4);
        assert!((res.x[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn respects_bounds() {
        // Unconstrained optimum at x=10, but box caps at 2.
        let res = lm_bounded(
            |x| vec![x[0] - 10.0],
            &[0.5],
            &[0.0],
            &[2.0],
            &LmOptions::default(),
        );
        assert!(res.x[0] <= 2.0 + 1e-12);
        assert!((res.x[0] - 2.0).abs() < 1e-9, "should hit the bound");
    }

    #[test]
    fn multistart_beats_bad_local_minimum() {
        // Double-well residual: r = (x² − 4)·(x − 3) has minima near ±2, 3;
        // a midpoint start can stall — multistart should find a zero.
        let f = |x: &[f64]| vec![(x[0] * x[0] - 4.0) * (x[0] - 3.0)];
        let res = lm_multistart(f, &[-5.0], &[5.0], 8, 1, &LmOptions::default());
        assert!(res.cost < 1e-10, "cost={}", res.cost);
    }

    #[test]
    fn handles_badly_scaled_parameters() {
        // Parameters at 1e-4 and 1e4 scales simultaneously.
        let ts: Vec<f64> = (1..30).map(|i| i as f64).collect();
        let ys: Vec<f64> = ts.iter().map(|t| 3e-4 * t + 2e4 / t).collect();
        let res = lm_bounded(
            |x| ts.iter().zip(&ys).map(|(t, y)| x[0] * t + x[1] / t - y).collect(),
            &[1e-5, 1e3],
            &[0.0, 0.0],
            &[1.0, 1e6],
            &LmOptions::default(),
        );
        assert!((res.x[0] - 3e-4).abs() / 3e-4 < 1e-3, "x0={}", res.x[0]);
        assert!((res.x[1] - 2e4).abs() / 2e4 < 1e-3, "x1={}", res.x[1]);
    }

    #[test]
    fn perfmodel_fit_recovers_synthetic_truth() {
        use crate::perfmodel::*;
        // Generate measurements from known parameters, fit, and check the
        // model reproduces the speedups (parameter identifiability is not
        // guaranteed — MSE is the paper's criterion).
        let model = PerfModel::with_ridge_point(150.0);
        let truth = PerfParams {
            bias: 0.02,
            k1: 3e-5,
            k2: 2.5e-4,
            k3: 2e-4,
            draft_bias: 0.0015,
            draft_k: 1e-5,
            reject_bias: 2e-4,
            reject_k: 1e-7,
            lambda: 0.55,
            s: 1.03,
        };
        let mut ms = Vec::new();
        for &k in &[2usize, 4, 8] {
            for &gamma in &[2usize, 4] {
                for &b in &[1usize, 4, 8, 16, 32, 64, 128] {
                    let mut m = Measurement {
                        batch: b,
                        gamma,
                        k,
                        e: 64,
                        sigma: 0.85,
                        speedup: 0.0,
                    };
                    m.speedup = model.compute_speedup(&truth, &m);
                    ms.push(m);
                }
            }
        }
        let bounds = ParamBounds {
            lo: [1e-3, 0.0, 1e-6, 0.0, 1e-5, 0.0, 0.0, 0.0, 0.2, 1.0 + 1e-9],
            hi: [0.1, 1.0, 1e-2, 1.0, 0.01, 1.0, 1e-2, 1e-4, 1.0, 2.0],
        };
        let (fitted, mse) = fit_perfmodel(&model, &ms, &bounds, 7);
        assert!(mse < 1e-3, "mse={mse} fitted={fitted:?}");
    }
}
