//! Small dense linear algebra for the least-squares optimizer: row-major
//! matrices, matrix products, and an LDLᵀ solver with diagonal-damping
//! fallback (all the LM normal equations need at n ≈ 10).

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, Vec::len);
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Mat {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// AᵀA (the Gauss–Newton normal matrix).
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let mut acc = 0.0;
                for r in 0..self.rows {
                    acc += self.get(r, i) * self.get(r, j);
                }
                out.set(i, j, acc);
                out.set(j, i, acc);
            }
        }
        out
    }

    /// Aᵀb.
    pub fn t_mul_vec(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            let br = b[r];
            for c in 0..self.cols {
                out[c] += self.get(r, c) * br;
            }
        }
        out
    }

    /// A·x.
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|r| (0..self.cols).map(|c| self.get(r, c) * x[c]).sum())
            .collect()
    }
}

/// Solve the symmetric system `A x = b` via LDLᵀ factorization; `A` must be
/// symmetric. Returns `None` if the factorization encounters a (near-)zero
/// pivot — callers add Levenberg damping and retry.
pub fn solve_symmetric(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.rows;
    assert_eq!(a.cols, n);
    assert_eq!(b.len(), n);
    // LDLᵀ: A = L D Lᵀ with unit lower-triangular L.
    let mut l = Mat::zeros(n, n);
    let mut d = vec![0.0; n];
    for j in 0..n {
        let mut dj = a.get(j, j);
        for k in 0..j {
            dj -= l.get(j, k) * l.get(j, k) * d[k];
        }
        if dj.abs() < 1e-300 || !dj.is_finite() {
            return None;
        }
        d[j] = dj;
        l.set(j, j, 1.0);
        for i in (j + 1)..n {
            let mut v = a.get(i, j);
            for k in 0..j {
                v -= l.get(i, k) * l.get(j, k) * d[k];
            }
            l.set(i, j, v / dj);
        }
    }
    // Forward solve L y = b.
    let mut y = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            let lik = l.get(i, k);
            y[i] -= lik * y[k];
        }
    }
    // Diagonal.
    for i in 0..n {
        y[i] /= d[i];
    }
    // Back solve Lᵀ x = y.
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            let lki = l.get(k, i);
            y[i] -= lki * y[k];
        }
    }
    if y.iter().all(|v| v.is_finite()) {
        Some(y)
    } else {
        None
    }
}

/// Euclidean norm.
pub fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gram_and_mul() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let g = a.gram();
        assert_eq!(g.get(0, 0), 35.0);
        assert_eq!(g.get(0, 1), 44.0);
        assert_eq!(g.get(1, 1), 56.0);
        assert_eq!(a.t_mul_vec(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
        assert_eq!(a.mul_vec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn solve_spd_system() {
        // A = [[4,1],[1,3]], b = [1,2] → x = [1/11, 7/11].
        let a = Mat::from_rows(vec![vec![4.0, 1.0], vec![1.0, 3.0]]);
        let x = solve_symmetric(&a, &[1.0, 2.0]).unwrap();
        assert!((x[0] - 1.0 / 11.0).abs() < 1e-12);
        assert!((x[1] - 7.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn solve_recovers_random_solutions() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seeded(42);
        for n in [1usize, 3, 6, 10] {
            // Build SPD A = MᵀM + I.
            let m = Mat::from_rows(
                (0..n)
                    .map(|_| (0..n).map(|_| rng.normal()).collect())
                    .collect(),
            );
            let mut a = m.gram();
            for i in 0..n {
                a.set(i, i, a.get(i, i) + 1.0);
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.mul_vec(&x_true);
            let x = solve_symmetric(&a, &b).unwrap();
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-8, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn singular_returns_none() {
        let a = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        assert!(solve_symmetric(&a, &[1.0, 1.0]).is_none());
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_panic() {
        Mat::from_rows(vec![vec![1.0], vec![1.0, 2.0]]);
    }
}
