//! Hardware platform descriptions: GPU roofline profiles (Eq. 1's ridge
//! point), multi-GPU platforms with tensor-parallel scaling, tile
//! quantization (the Fig. 5 sawtooth), the CPU-offload bandwidth mode
//! discussed in §3.4, and expert-parallel (EP) sharding topologies
//! ([`Topology`] / [`ShardingSpec`]) for the §3.4 "extensive EP
//! configurations" scale axis.
//!
//! Two distinct multi-device axes compose here:
//! - **Tensor parallelism** ([`Platform::n_gpus`]): every weight matrix is
//!   split across the TP group, which acts as one fat device with
//!   aggregated FLOPs/bandwidth plus per-layer all-reduces. This is the
//!   paper's 2×/4× GPU setting.
//! - **Expert parallelism** ([`ShardingSpec`]): `d` whole [`Platform`]s
//!   (EP ranks) each own `E/d` routed experts; non-expert weights are
//!   replicated and sequences are data-parallel (per-rank batch `B/d`),
//!   while tokens reach remote experts through all-to-all
//!   dispatch/combine on the [`Topology`] fabric. This is how
//!   Qwen2-57B-class sparse MoEs are actually served at rack scale.
//!
//! The paper anonymizes its devices as GPU-A/B/C. We bind them to public
//! roofline numbers that reproduce the paper's orderings:
//! - peak SD speedup grows with the ridge point (2×GPU-B > 2×GPU-A),
//! - GPU-C matches GPU-A's chip but has a slow interconnect, making 4×GPU-C
//!   slower in absolute time yet slightly *better* in target efficiency
//!   (comm time is γ-independent, diluting the verify-term growth).

/// A single accelerator's roofline profile.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuProfile {
    pub name: String,
    /// Peak dense half-precision throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Device memory, bytes.
    pub mem_cap: f64,
    /// GEMM tile granularity (tokens) for quantization effects [47].
    pub tile: usize,
}

impl GpuProfile {
    /// Ridge point (Eq. 1): FLOPs per byte at the memory/compute crossover.
    pub fn ridge_point(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    /// Time to process `flops` of compute and `bytes` of memory traffic on
    /// one device under the overlap (roofline) assumption, with achievable
    /// fractions of peak.
    pub fn op_time(&self, flops: f64, bytes: f64, eff: Efficiency) -> f64 {
        let t_compute = flops / (self.peak_flops * eff.compute);
        let t_memory = bytes / (self.mem_bw * eff.memory);
        t_compute.max(t_memory)
    }
}

/// Achievable fractions of peak compute / memory bandwidth (GPUs never hit
/// 100%; the perf-model's λ and s parameters absorb the same slack on the
/// analytic side).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Efficiency {
    pub compute: f64,
    pub memory: f64,
}

impl Default for Efficiency {
    fn default() -> Self {
        // Sustained fractions of peak for serving-shaped work. Compute
        // efficiency is deliberately below big-GEMM numbers: decode/verify
        // GEMMs have token dims of O(1-100), far under the tile sizes that
        // saturate tensor cores — the same effect the paper's empirical
        // ridge-point ratio λ ∈ [0.2, 1] absorbs on the modeling side.
        Efficiency {
            compute: 0.35,
            memory: 0.80,
        }
    }
}

/// GPU-A — A100-SXM-class: 312 TFLOP/s bf16, 2039 GB/s, RP ≈ 153.
pub fn gpu_a() -> GpuProfile {
    GpuProfile {
        name: "GPU-A".into(),
        peak_flops: 312e12,
        mem_bw: 2039e9,
        mem_cap: 80e9,
        tile: 64,
    }
}

/// GPU-B — H800-class: 990 TFLOP/s bf16, 3350 GB/s, RP ≈ 295. Higher ridge
/// point than GPU-A ⇒ more spare arithmetic for verification (§4.1 obs. 1).
pub fn gpu_b() -> GpuProfile {
    GpuProfile {
        name: "GPU-B".into(),
        peak_flops: 990e12,
        mem_bw: 3350e9,
        mem_cap: 80e9,
        tile: 128,
    }
}

/// GPU-C — A100-PCIe-class: same chip roofline as GPU-A but a much slower
/// interconnect (no NVLink), so multi-GPU deployments pay a large
/// γ-independent communication constant.
pub fn gpu_c() -> GpuProfile {
    GpuProfile {
        name: "GPU-C".into(),
        peak_flops: 312e12,
        mem_bw: 1935e9,
        mem_cap: 80e9,
        tile: 64,
    }
}

/// A deployment platform: `n_gpus` identical GPUs in tensor parallelism,
/// with an all-reduce interconnect and (optionally) CPU-offloaded expert
/// weights (§3.4 "Extended configurations").
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    pub gpu: GpuProfile,
    pub n_gpus: usize,
    /// Per-direction interconnect bandwidth, bytes/s (NVLink ≈ 300 GB/s,
    /// PCIe 4.0 x16 ≈ 32 GB/s).
    pub interconnect_bw: f64,
    /// Fixed per-collective latency, seconds.
    pub comm_latency: f64,
    /// If set, expert weights stream from host memory at this bandwidth
    /// (bytes/s) instead of HBM — the offloading scenario.
    pub offload_bw: Option<f64>,
    pub eff: Efficiency,
}

impl Platform {
    pub fn new(gpu: GpuProfile, n_gpus: usize, interconnect_bw: f64) -> Platform {
        Platform {
            gpu,
            n_gpus,
            interconnect_bw,
            comm_latency: 10e-6,
            offload_bw: None,
            eff: Efficiency::default(),
        }
    }

    pub fn name(&self) -> String {
        format!("{}x{}", self.n_gpus, self.gpu.name)
    }

    /// Aggregate compute across the TP group.
    pub fn total_flops(&self) -> f64 {
        self.gpu.peak_flops * self.n_gpus as f64
    }

    /// Aggregate HBM bandwidth across the TP group.
    pub fn total_mem_bw(&self) -> f64 {
        self.gpu.mem_bw * self.n_gpus as f64
    }

    /// Bandwidth used to *load model weights*: HBM normally, PCIe when
    /// offloading (which is what makes offloaded MoEs extremely
    /// memory-bound, §3.4).
    pub fn weight_bw(&self) -> f64 {
        match self.offload_bw {
            Some(bw) => bw,
            None => self.total_mem_bw(),
        }
    }

    /// Time for a sharded op: weights and compute split across GPUs.
    pub fn sharded_op_time(&self, flops: f64, weight_bytes: f64, act_bytes: f64) -> f64 {
        let t_compute = flops / (self.total_flops() * self.eff.compute);
        let t_weights = weight_bytes / (self.weight_bw() * self.eff.memory);
        let t_act = act_bytes / (self.total_mem_bw() * self.eff.memory);
        t_compute.max(t_weights + t_act)
    }

    /// All-reduce time for `bytes` of activations (ring): 2·(n−1)/n of the
    /// payload over the slowest link, plus fixed latency. Zero for 1 GPU.
    pub fn allreduce_time(&self, bytes: f64) -> f64 {
        if self.n_gpus <= 1 {
            return 0.0;
        }
        let n = self.n_gpus as f64;
        self.comm_latency + 2.0 * (n - 1.0) / n * bytes / self.interconnect_bw
    }

    /// Platform-level ridge point (tokens scale): how many tokens per
    /// weight-load before compute becomes the bottleneck.
    pub fn ridge_point(&self) -> f64 {
        self.total_flops() / self.weight_bw()
    }

    pub fn with_offload(mut self, host_bw: f64) -> Platform {
        self.offload_bw = Some(host_bw);
        self
    }
}

/// The four platforms used in Tables 1–2 and Figs. 2/5.
pub fn platform_2x_gpu_a() -> Platform {
    Platform::new(gpu_a(), 2, 300e9)
}

pub fn platform_2x_gpu_b() -> Platform {
    Platform::new(gpu_b(), 2, 200e9)
}

pub fn platform_4x_gpu_a() -> Platform {
    Platform::new(gpu_a(), 4, 300e9)
}

pub fn platform_4x_gpu_c() -> Platform {
    Platform::new(gpu_c(), 4, 24e9)
}

pub fn platform_by_name(name: &str) -> anyhow::Result<Platform> {
    match name {
        "2xGPU-A" => Ok(platform_2x_gpu_a()),
        "2xGPU-B" => Ok(platform_2x_gpu_b()),
        "4xGPU-A" => Ok(platform_4x_gpu_a()),
        "4xGPU-C" => Ok(platform_4x_gpu_c()),
        other => anyhow::bail!("unknown platform `{other}` (want 2xGPU-A/2xGPU-B/4xGPU-A/4xGPU-C)"),
    }
}

/// Inter-rank fabric of an expert-parallel group: how many EP ranks there
/// are and how fast tokens move between them during MoE dispatch/combine.
///
/// `devices == 1` is the degenerate single-rank topology — no fabric, no
/// all-to-all — and every sharded code path is required to collapse to the
/// unsharded one bit-for-bit there (property-tested in
/// `rust/tests/prop_invariants.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// EP group size `d` (each rank is a full [`Platform`]).
    pub devices: usize,
    /// Per-rank, per-direction all-to-all bandwidth, bytes/s.
    pub link_bw: f64,
    /// Fixed latency per collective launch, seconds.
    pub link_latency: f64,
}

impl Topology {
    /// The degenerate one-rank topology (no fabric).
    pub fn single() -> Topology {
        Topology {
            devices: 1,
            link_bw: 300e9,
            link_latency: 0.0,
        }
    }

    /// NVLink/NVSwitch-class fabric: ~250 GB/s per direction, ~10 µs
    /// collective launch.
    pub fn nvlink(devices: usize) -> Topology {
        Topology {
            devices,
            link_bw: 250e9,
            link_latency: 10e-6,
        }
    }

    /// PCIe 4.0 x16-class fabric: ~32 GB/s per direction, ~25 µs launch —
    /// the communication-bound regime (cf. the 4×GPU-C platform).
    pub fn pcie(devices: usize) -> Topology {
        Topology {
            devices,
            link_bw: 32e9,
            link_latency: 25e-6,
        }
    }

    /// Fully custom fabric.
    pub fn custom(devices: usize, link_bw: f64, link_latency: f64) -> Topology {
        Topology {
            devices,
            link_bw,
            link_latency,
        }
    }

    /// Short identifier for reports, e.g. `ep4@250GB/s`.
    pub fn name(&self) -> String {
        format!("ep{}@{:.0}GB/s", self.devices, self.link_bw / 1e9)
    }
}

/// Everything a cost model needs to price one expert-parallel deployment:
/// the fabric, a routing-imbalance factor, and the all-to-all payload scale
/// (so arch-less models like [`crate::perfmodel::PerfModel`] can price the
/// fabric without knowing hidden sizes).
///
/// Construct with [`ShardingSpec::for_arch`] when a [`crate::arch::ModelArch`]
/// is at hand (derives the payload exactly), or [`ShardingSpec::single`]
/// for the unsharded baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardingSpec {
    pub topology: Topology,
    /// Straggler multiplier on the per-rank expert arm (balanced routing
    /// = 1.0; the hottest rank sees `imbalance ×` the mean expert load).
    pub imbalance: f64,
    /// Dispatch + combine bytes crossing the expert fabric per *global*
    /// token for one full forward pass: `2 · layers · K · hidden · dtype`
    /// for a MoE architecture, 0 for dense (no routed experts, no
    /// all-to-all).
    pub payload_bytes_per_token: f64,
    /// Collective launches per forward (2 per MoE layer).
    pub collectives_per_forward: f64,
}

impl ShardingSpec {
    /// The unsharded baseline (one rank, zero fabric cost).
    pub fn single() -> ShardingSpec {
        ShardingSpec {
            topology: Topology::single(),
            imbalance: 1.0,
            payload_bytes_per_token: 0.0,
            collectives_per_forward: 0.0,
        }
    }

    /// Topology-only spec with zero payload (an *ideal* fabric — useful
    /// for ablating bandwidth effects out of a sweep).
    pub fn new(topology: Topology) -> ShardingSpec {
        ShardingSpec {
            topology,
            imbalance: 1.0,
            payload_bytes_per_token: 0.0,
            collectives_per_forward: 0.0,
        }
    }

    /// Derive the payload scale from a model architecture: each token's
    /// hidden state is scattered to its K experts and gathered back, per
    /// MoE layer. Dense architectures get a zero payload (EP is a no-op
    /// for them).
    pub fn for_arch(topology: Topology, arch: &crate::arch::ModelArch) -> ShardingSpec {
        let (payload, collectives) = if arch.is_moe() {
            (
                2.0 * arch.layers as f64
                    * arch.topk() as f64
                    * arch.hidden as f64
                    * arch.dtype_bytes,
                2.0 * arch.layers as f64,
            )
        } else {
            (0.0, 0.0)
        };
        ShardingSpec {
            topology,
            imbalance: 1.0,
            payload_bytes_per_token: payload,
            collectives_per_forward: collectives,
        }
    }

    /// Builder: set the straggler factor (≥ 1).
    pub fn with_imbalance(mut self, imbalance: f64) -> ShardingSpec {
        self.imbalance = imbalance;
        self
    }

    /// EP group size `d`.
    pub fn devices(&self) -> usize {
        self.topology.devices.max(1)
    }

    pub fn is_sharded(&self) -> bool {
        self.devices() > 1
    }

    /// All-to-all time for one forward pass over `tokens` *global* tokens:
    /// each rank exchanges its `tokens/d` share of the payload, of which
    /// the [`crate::theory::ep_remote_fraction`] crosses its fabric link,
    /// plus the per-collective launch latency. Zero for one rank.
    pub fn comm_time(&self, tokens: f64) -> f64 {
        let d = self.devices() as f64;
        if self.devices() <= 1 {
            return 0.0;
        }
        let remote = crate::theory::ep_remote_fraction(self.devices());
        let per_rank_bytes = tokens / d * self.payload_bytes_per_token * remote;
        self.collectives_per_forward * self.topology.link_latency
            + per_rank_bytes / self.topology.link_bw
    }

    /// Loud validation for API boundaries (config loading, CLI).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.topology.devices >= 1, "topology needs >= 1 device");
        anyhow::ensure!(
            self.topology.link_bw > 0.0,
            "link bandwidth must be positive"
        );
        anyhow::ensure!(self.topology.link_latency >= 0.0, "negative link latency");
        anyhow::ensure!(self.imbalance >= 1.0, "imbalance factor must be >= 1");
        anyhow::ensure!(
            self.payload_bytes_per_token >= 0.0 && self.collectives_per_forward >= 0.0,
            "negative payload/collective counts"
        );
        Ok(())
    }
}

/// Tile quantization [47]: GEMMs process token counts rounded up to the
/// device tile, so effective work is `ceil(t / tile) · tile`. This produces
/// the sawtooth in the paper's Fig. 5(c).
pub fn tile_quantize(tokens: f64, tile: usize) -> f64 {
    if tokens <= 0.0 {
        return 0.0;
    }
    (tokens / tile as f64).ceil() * tile as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ridge_points_reproduce_paper_ordering() {
        // §4.1 observation (1): GPU-B's ridge point exceeds GPU-A's.
        assert!(gpu_b().ridge_point() > gpu_a().ridge_point());
        // GPU-C ≈ GPU-A chip.
        assert!((gpu_c().ridge_point() - gpu_a().ridge_point()).abs() < 15.0);
        // Known magnitudes.
        assert!((gpu_a().ridge_point() - 153.0).abs() < 3.0);
        assert!((gpu_b().ridge_point() - 295.0).abs() < 5.0);
    }

    #[test]
    fn op_time_roofline_crossover() {
        let g = gpu_a();
        let eff = Efficiency::default();
        // Tiny compute, big memory → memory-bound: time tracks bytes.
        let t_mem = g.op_time(1e6, 1e9, eff);
        assert!((t_mem - 1e9 / (g.mem_bw * eff.memory)).abs() / t_mem < 1e-9);
        // Huge compute → compute-bound.
        let t_cmp = g.op_time(1e15, 1e6, eff);
        assert!((t_cmp - 1e15 / (g.peak_flops * eff.compute)).abs() / t_cmp < 1e-9);
    }

    #[test]
    fn allreduce_scales_with_bytes_and_is_zero_single_gpu() {
        let p = platform_2x_gpu_a();
        assert!(p.allreduce_time(2e6) > p.allreduce_time(1e6));
        let single = Platform::new(gpu_a(), 1, 300e9);
        assert_eq!(single.allreduce_time(1e9), 0.0);
    }

    #[test]
    fn gpu_c_platform_has_slow_interconnect() {
        let a = platform_4x_gpu_a();
        let c = platform_4x_gpu_c();
        assert!(c.allreduce_time(1e6) > a.allreduce_time(1e6));
    }

    #[test]
    fn offload_reduces_weight_bandwidth() {
        let p = platform_2x_gpu_a();
        let off = p.clone().with_offload(30e9);
        assert!(off.weight_bw() < p.weight_bw() / 50.0);
        assert!(off.ridge_point() > p.ridge_point() * 50.0);
    }

    #[test]
    fn tile_quantize_sawtooth() {
        assert_eq!(tile_quantize(1.0, 64), 64.0);
        assert_eq!(tile_quantize(64.0, 64), 64.0);
        assert_eq!(tile_quantize(65.0, 64), 128.0);
        assert_eq!(tile_quantize(0.0, 64), 0.0);
    }

    #[test]
    fn topology_presets_and_name() {
        let nv = Topology::nvlink(4);
        let pc = Topology::pcie(4);
        assert_eq!(nv.devices, 4);
        assert!(nv.link_bw > pc.link_bw * 5.0, "NVLink should dwarf PCIe");
        assert!(pc.link_latency > nv.link_latency);
        assert_eq!(nv.name(), "ep4@250GB/s");
        assert_eq!(Topology::single().devices, 1);
    }

    #[test]
    fn sharding_spec_for_arch_payload() {
        let arch = crate::arch::presets::qwen2_57b_a14b();
        let spec = ShardingSpec::for_arch(Topology::nvlink(4), &arch);
        // 2 · layers · K · hidden · dtype = 2 · 28 · 8 · 3584 · 2.
        let want = 2.0 * 28.0 * 8.0 * 3584.0 * 2.0;
        assert_eq!(spec.payload_bytes_per_token, want);
        assert_eq!(spec.collectives_per_forward, 56.0);
        assert!(spec.validate().is_ok());
        // Dense arch: EP is a no-op, zero payload.
        let dense = ShardingSpec::for_arch(Topology::nvlink(4), &crate::arch::presets::opt_30b());
        assert_eq!(dense.payload_bytes_per_token, 0.0);
    }

    #[test]
    fn comm_time_zero_single_scales_with_tokens_and_fabric() {
        let arch = crate::arch::presets::qwen2_57b_a14b();
        assert_eq!(ShardingSpec::single().comm_time(1e6), 0.0);
        let nv = ShardingSpec::for_arch(Topology::nvlink(4), &arch);
        let pc = ShardingSpec::for_arch(Topology::pcie(4), &arch);
        assert!(nv.comm_time(256.0) > nv.comm_time(32.0));
        assert!(
            pc.comm_time(256.0) > 5.0 * nv.comm_time(256.0),
            "PCIe all-to-all should be far slower: {} vs {}",
            pc.comm_time(256.0),
            nv.comm_time(256.0)
        );
        // Latency floor: even one token pays the collective launches.
        assert!(nv.comm_time(1.0) >= 56.0 * 10e-6);
    }

    #[test]
    fn sharding_spec_validation_rejects_bad_knobs() {
        let arch = crate::arch::presets::qwen2_57b_a14b();
        let mut spec = ShardingSpec::for_arch(Topology::nvlink(2), &arch);
        spec.imbalance = 0.5;
        assert!(spec.validate().is_err());
        let bad_bw = ShardingSpec::new(Topology::custom(2, 0.0, 1e-6));
        assert!(bad_bw.validate().is_err());
        let no_dev = ShardingSpec::new(Topology::custom(0, 1e9, 0.0));
        assert!(no_dev.validate().is_err());
    }

    #[test]
    fn platform_lookup() {
        for name in ["2xGPU-A", "2xGPU-B", "4xGPU-A", "4xGPU-C"] {
            assert_eq!(platform_by_name(name).unwrap().name(), name);
        }
        assert!(platform_by_name("8xGPU-Z").is_err());
    }
}
