//! Hardware platform descriptions: GPU roofline profiles (Eq. 1's ridge
//! point), multi-GPU platforms with tensor-parallel scaling, tile
//! quantization (the Fig. 5 sawtooth), and the CPU-offload bandwidth mode
//! discussed in §3.4.
//!
//! The paper anonymizes its devices as GPU-A/B/C. We bind them to public
//! roofline numbers that reproduce the paper's orderings:
//! - peak SD speedup grows with the ridge point (2×GPU-B > 2×GPU-A),
//! - GPU-C matches GPU-A's chip but has a slow interconnect, making 4×GPU-C
//!   slower in absolute time yet slightly *better* in target efficiency
//!   (comm time is γ-independent, diluting the verify-term growth).

/// A single accelerator's roofline profile.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuProfile {
    pub name: String,
    /// Peak dense half-precision throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Device memory, bytes.
    pub mem_cap: f64,
    /// GEMM tile granularity (tokens) for quantization effects [47].
    pub tile: usize,
}

impl GpuProfile {
    /// Ridge point (Eq. 1): FLOPs per byte at the memory/compute crossover.
    pub fn ridge_point(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    /// Time to process `flops` of compute and `bytes` of memory traffic on
    /// one device under the overlap (roofline) assumption, with achievable
    /// fractions of peak.
    pub fn op_time(&self, flops: f64, bytes: f64, eff: Efficiency) -> f64 {
        let t_compute = flops / (self.peak_flops * eff.compute);
        let t_memory = bytes / (self.mem_bw * eff.memory);
        t_compute.max(t_memory)
    }
}

/// Achievable fractions of peak compute / memory bandwidth (GPUs never hit
/// 100%; the perf-model's λ and s parameters absorb the same slack on the
/// analytic side).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Efficiency {
    pub compute: f64,
    pub memory: f64,
}

impl Default for Efficiency {
    fn default() -> Self {
        // Sustained fractions of peak for serving-shaped work. Compute
        // efficiency is deliberately below big-GEMM numbers: decode/verify
        // GEMMs have token dims of O(1-100), far under the tile sizes that
        // saturate tensor cores — the same effect the paper's empirical
        // ridge-point ratio λ ∈ [0.2, 1] absorbs on the modeling side.
        Efficiency {
            compute: 0.35,
            memory: 0.80,
        }
    }
}

/// GPU-A — A100-SXM-class: 312 TFLOP/s bf16, 2039 GB/s, RP ≈ 153.
pub fn gpu_a() -> GpuProfile {
    GpuProfile {
        name: "GPU-A".into(),
        peak_flops: 312e12,
        mem_bw: 2039e9,
        mem_cap: 80e9,
        tile: 64,
    }
}

/// GPU-B — H800-class: 990 TFLOP/s bf16, 3350 GB/s, RP ≈ 295. Higher ridge
/// point than GPU-A ⇒ more spare arithmetic for verification (§4.1 obs. 1).
pub fn gpu_b() -> GpuProfile {
    GpuProfile {
        name: "GPU-B".into(),
        peak_flops: 990e12,
        mem_bw: 3350e9,
        mem_cap: 80e9,
        tile: 128,
    }
}

/// GPU-C — A100-PCIe-class: same chip roofline as GPU-A but a much slower
/// interconnect (no NVLink), so multi-GPU deployments pay a large
/// γ-independent communication constant.
pub fn gpu_c() -> GpuProfile {
    GpuProfile {
        name: "GPU-C".into(),
        peak_flops: 312e12,
        mem_bw: 1935e9,
        mem_cap: 80e9,
        tile: 64,
    }
}

/// A deployment platform: `n_gpus` identical GPUs in tensor parallelism,
/// with an all-reduce interconnect and (optionally) CPU-offloaded expert
/// weights (§3.4 "Extended configurations").
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    pub gpu: GpuProfile,
    pub n_gpus: usize,
    /// Per-direction interconnect bandwidth, bytes/s (NVLink ≈ 300 GB/s,
    /// PCIe 4.0 x16 ≈ 32 GB/s).
    pub interconnect_bw: f64,
    /// Fixed per-collective latency, seconds.
    pub comm_latency: f64,
    /// If set, expert weights stream from host memory at this bandwidth
    /// (bytes/s) instead of HBM — the offloading scenario.
    pub offload_bw: Option<f64>,
    pub eff: Efficiency,
}

impl Platform {
    pub fn new(gpu: GpuProfile, n_gpus: usize, interconnect_bw: f64) -> Platform {
        Platform {
            gpu,
            n_gpus,
            interconnect_bw,
            comm_latency: 10e-6,
            offload_bw: None,
            eff: Efficiency::default(),
        }
    }

    pub fn name(&self) -> String {
        format!("{}x{}", self.n_gpus, self.gpu.name)
    }

    /// Aggregate compute across the TP group.
    pub fn total_flops(&self) -> f64 {
        self.gpu.peak_flops * self.n_gpus as f64
    }

    /// Aggregate HBM bandwidth across the TP group.
    pub fn total_mem_bw(&self) -> f64 {
        self.gpu.mem_bw * self.n_gpus as f64
    }

    /// Bandwidth used to *load model weights*: HBM normally, PCIe when
    /// offloading (which is what makes offloaded MoEs extremely
    /// memory-bound, §3.4).
    pub fn weight_bw(&self) -> f64 {
        match self.offload_bw {
            Some(bw) => bw,
            None => self.total_mem_bw(),
        }
    }

    /// Time for a sharded op: weights and compute split across GPUs.
    pub fn sharded_op_time(&self, flops: f64, weight_bytes: f64, act_bytes: f64) -> f64 {
        let t_compute = flops / (self.total_flops() * self.eff.compute);
        let t_weights = weight_bytes / (self.weight_bw() * self.eff.memory);
        let t_act = act_bytes / (self.total_mem_bw() * self.eff.memory);
        t_compute.max(t_weights + t_act)
    }

    /// All-reduce time for `bytes` of activations (ring): 2·(n−1)/n of the
    /// payload over the slowest link, plus fixed latency. Zero for 1 GPU.
    pub fn allreduce_time(&self, bytes: f64) -> f64 {
        if self.n_gpus <= 1 {
            return 0.0;
        }
        let n = self.n_gpus as f64;
        self.comm_latency + 2.0 * (n - 1.0) / n * bytes / self.interconnect_bw
    }

    /// Platform-level ridge point (tokens scale): how many tokens per
    /// weight-load before compute becomes the bottleneck.
    pub fn ridge_point(&self) -> f64 {
        self.total_flops() / self.weight_bw()
    }

    pub fn with_offload(mut self, host_bw: f64) -> Platform {
        self.offload_bw = Some(host_bw);
        self
    }
}

/// The four platforms used in Tables 1–2 and Figs. 2/5.
pub fn platform_2x_gpu_a() -> Platform {
    Platform::new(gpu_a(), 2, 300e9)
}

pub fn platform_2x_gpu_b() -> Platform {
    Platform::new(gpu_b(), 2, 200e9)
}

pub fn platform_4x_gpu_a() -> Platform {
    Platform::new(gpu_a(), 4, 300e9)
}

pub fn platform_4x_gpu_c() -> Platform {
    Platform::new(gpu_c(), 4, 24e9)
}

pub fn platform_by_name(name: &str) -> anyhow::Result<Platform> {
    match name {
        "2xGPU-A" => Ok(platform_2x_gpu_a()),
        "2xGPU-B" => Ok(platform_2x_gpu_b()),
        "4xGPU-A" => Ok(platform_4x_gpu_a()),
        "4xGPU-C" => Ok(platform_4x_gpu_c()),
        other => anyhow::bail!("unknown platform `{other}` (want 2xGPU-A/2xGPU-B/4xGPU-A/4xGPU-C)"),
    }
}

/// Tile quantization [47]: GEMMs process token counts rounded up to the
/// device tile, so effective work is `ceil(t / tile) · tile`. This produces
/// the sawtooth in the paper's Fig. 5(c).
pub fn tile_quantize(tokens: f64, tile: usize) -> f64 {
    if tokens <= 0.0 {
        return 0.0;
    }
    (tokens / tile as f64).ceil() * tile as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ridge_points_reproduce_paper_ordering() {
        // §4.1 observation (1): GPU-B's ridge point exceeds GPU-A's.
        assert!(gpu_b().ridge_point() > gpu_a().ridge_point());
        // GPU-C ≈ GPU-A chip.
        assert!((gpu_c().ridge_point() - gpu_a().ridge_point()).abs() < 15.0);
        // Known magnitudes.
        assert!((gpu_a().ridge_point() - 153.0).abs() < 3.0);
        assert!((gpu_b().ridge_point() - 295.0).abs() < 5.0);
    }

    #[test]
    fn op_time_roofline_crossover() {
        let g = gpu_a();
        let eff = Efficiency::default();
        // Tiny compute, big memory → memory-bound: time tracks bytes.
        let t_mem = g.op_time(1e6, 1e9, eff);
        assert!((t_mem - 1e9 / (g.mem_bw * eff.memory)).abs() / t_mem < 1e-9);
        // Huge compute → compute-bound.
        let t_cmp = g.op_time(1e15, 1e6, eff);
        assert!((t_cmp - 1e15 / (g.peak_flops * eff.compute)).abs() / t_cmp < 1e-9);
    }

    #[test]
    fn allreduce_scales_with_bytes_and_is_zero_single_gpu() {
        let p = platform_2x_gpu_a();
        assert!(p.allreduce_time(2e6) > p.allreduce_time(1e6));
        let single = Platform::new(gpu_a(), 1, 300e9);
        assert_eq!(single.allreduce_time(1e9), 0.0);
    }

    #[test]
    fn gpu_c_platform_has_slow_interconnect() {
        let a = platform_4x_gpu_a();
        let c = platform_4x_gpu_c();
        assert!(c.allreduce_time(1e6) > a.allreduce_time(1e6));
    }

    #[test]
    fn offload_reduces_weight_bandwidth() {
        let p = platform_2x_gpu_a();
        let off = p.clone().with_offload(30e9);
        assert!(off.weight_bw() < p.weight_bw() / 50.0);
        assert!(off.ridge_point() > p.ridge_point() * 50.0);
    }

    #[test]
    fn tile_quantize_sawtooth() {
        assert_eq!(tile_quantize(1.0, 64), 64.0);
        assert_eq!(tile_quantize(64.0, 64), 64.0);
        assert_eq!(tile_quantize(65.0, 64), 128.0);
        assert_eq!(tile_quantize(0.0, 64), 0.0);
    }

    #[test]
    fn platform_lookup() {
        for name in ["2xGPU-A", "2xGPU-B", "4xGPU-A", "4xGPU-C"] {
            assert_eq!(platform_by_name(name).unwrap().name(), name);
        }
        assert!(platform_by_name("8xGPU-Z").is_err());
    }
}
