//! Launcher configuration: a JSON file describing what to serve/simulate.
//!
//! Example (`examples/configs/private_serving.json`):
//! ```json
//! {
//!   "mode": "synthetic",
//!   "model": "qwen2-57b-a14b",
//!   "draft": "qwen2-0.5b",
//!   "platform": "2xGPU-A",
//!   "gamma": 4,
//!   "dataset": "humaneval",
//!   "temperature": 0.0,
//!   "max_batch": 32,
//!   "max_new_tokens": 128,
//!   "kv_blocks": 4096,
//!   "kv_block_size": 16,
//!   "seed": 0
//! }
//! ```

use crate::batching::Buckets;
use crate::control::{ControlConfig, CostModelSpec};
use crate::engine::EngineConfig;
use crate::kvcache::KvConfig;
use crate::scheduler::SchedulerConfig;
use crate::simulator::ExecSim;
use crate::util::json::Json;
use std::path::Path;

/// Which backend the launcher builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Paper-scale roofline-simulated serving.
    Synthetic,
    /// The tiny real model via PJRT artifacts.
    Hlo,
}

/// Full launcher configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub mode: Mode,
    pub model: String,
    pub draft: String,
    pub platform: String,
    pub gamma: usize,
    pub dataset: String,
    pub temperature: f64,
    pub max_batch: usize,
    pub max_new_tokens: usize,
    pub kv_blocks: usize,
    pub kv_block_size: usize,
    pub seed: u64,
    /// Artifacts directory (HLO mode).
    pub artifacts_dir: String,
    /// Enable the adaptive speculation control plane (synthetic mode):
    /// online model-guided γ/batch co-tuning instead of the fixed γ.
    pub adaptive: bool,
    /// Enable ragged rounds (per-sequence γᵢ refined from windowed
    /// per-sequence α̂ᵢ). Requires `adaptive`; the `--ragged` CLI flag
    /// sets both.
    pub ragged: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            mode: Mode::Synthetic,
            model: "qwen2-57b-a14b".into(),
            draft: "qwen2-0.5b".into(),
            platform: "2xGPU-A".into(),
            gamma: 4,
            dataset: "humaneval".into(),
            temperature: 0.0,
            max_batch: 32,
            max_new_tokens: 128,
            kv_blocks: 4096,
            kv_block_size: 16,
            seed: 0,
            artifacts_dir: "artifacts".into(),
            adaptive: false,
            ragged: false,
        }
    }
}

impl Config {
    pub fn from_json(j: &Json) -> anyhow::Result<Config> {
        let d = Config::default();
        let str_or = |key: &str, default: &str| -> String {
            j.get(key)
                .and_then(Json::as_str)
                .unwrap_or(default)
                .to_string()
        };
        let usize_or =
            |key: &str, default: usize| j.get(key).and_then(Json::as_usize).unwrap_or(default);
        let mode = match str_or("mode", "synthetic").as_str() {
            "synthetic" => Mode::Synthetic,
            "hlo" => Mode::Hlo,
            other => anyhow::bail!("unknown mode `{other}` (want synthetic|hlo)"),
        };
        let cfg = Config {
            mode,
            model: str_or("model", &d.model),
            draft: str_or("draft", &d.draft),
            platform: str_or("platform", &d.platform),
            gamma: usize_or("gamma", d.gamma),
            dataset: str_or("dataset", &d.dataset),
            temperature: j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0),
            max_batch: usize_or("max_batch", d.max_batch),
            max_new_tokens: usize_or("max_new_tokens", d.max_new_tokens),
            kv_blocks: usize_or("kv_blocks", d.kv_blocks),
            kv_block_size: usize_or("kv_block_size", d.kv_block_size),
            seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            artifacts_dir: str_or("artifacts_dir", &d.artifacts_dir),
            adaptive: j.get("adaptive").and_then(Json::as_bool).unwrap_or(false),
            ragged: j.get("ragged").and_then(Json::as_bool).unwrap_or(false),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> anyhow::Result<Config> {
        Config::from_json(&Json::parse_file(path)?)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.gamma <= 16, "gamma {} unreasonably large", self.gamma);
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(
            self.kv_blocks >= 1 && self.kv_block_size >= 1,
            "invalid KV geometry"
        );
        anyhow::ensure!(
            (0.0..=2.0).contains(&self.temperature),
            "temperature out of range"
        );
        if self.mode == Mode::Synthetic {
            crate::arch::presets::by_name(&self.model)?;
            crate::arch::presets::by_name(&self.draft)?;
            crate::hardware::platform_by_name(&self.platform)?;
        }
        anyhow::ensure!(
            !(self.adaptive && self.mode == Mode::Hlo),
            "adaptive control requires synthetic mode (no calibrated cost model for \
             the HLO backend yet)"
        );
        anyhow::ensure!(
            !(self.ragged && !self.adaptive),
            "ragged speculation requires the adaptive control plane (use --ragged, \
             which implies --adaptive, or set both in the config file)"
        );
        Ok(())
    }

    /// The adaptive controller configuration this config implies:
    /// model-guided over the roofline simulator of the configured
    /// (model, draft, platform), with the workload-calibrated α as prior.
    /// `None` when `adaptive` is off.
    pub fn control_config(&self) -> anyhow::Result<Option<ControlConfig>> {
        if !self.adaptive {
            return Ok(None);
        }
        anyhow::ensure!(
            self.mode == Mode::Synthetic,
            "adaptive control requires synthetic mode"
        );
        let target = crate::arch::presets::by_name(&self.model)?;
        let draft = crate::arch::presets::by_name(&self.draft)?;
        let platform = crate::hardware::platform_by_name(&self.platform)?;
        let alpha = crate::workload::calibrated_alpha(
            crate::workload::model_family(&self.model),
            crate::workload::Dataset::by_name(&self.dataset)?,
            self.temperature,
            self.gamma.clamp(2, 4),
        );
        // Oracle matches the serve backend exactly: both the target and
        // the draft are priced on the full deployment platform (the same
        // ExecSim construction `serve` uses for the synthetic backend).
        let tsim = ExecSim::new(target, platform.clone());
        let dsim = ExecSim::new(draft, platform);
        Ok(Some(ControlConfig {
            alpha_prior: alpha,
            ragged: self.ragged,
            ..ControlConfig::model_guided(CostModelSpec::roofline(tsim, dsim))
        }))
    }

    /// Derive the engine configuration (including the adaptive controller
    /// when `adaptive` is set — the flag is honored here, not just by the
    /// serve binary).
    pub fn engine_config(&self) -> anyhow::Result<EngineConfig> {
        Ok(EngineConfig {
            gamma: self.gamma,
            kv: KvConfig {
                num_blocks: self.kv_blocks,
                block_size: self.kv_block_size,
            },
            scheduler: SchedulerConfig {
                max_batch: self.max_batch,
                admit_reserve_tokens: self.max_new_tokens.min(64),
                tpot_slo: None,
            },
            buckets: Buckets::pow2_up_to(self.max_batch.max(1)),
            seed: self.seed,
            control: self.control_config()?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "mode",
                match self.mode {
                    Mode::Synthetic => "synthetic".into(),
                    Mode::Hlo => "hlo".into(),
                },
            ),
            ("model", self.model.as_str().into()),
            ("draft", self.draft.as_str().into()),
            ("platform", self.platform.as_str().into()),
            ("gamma", self.gamma.into()),
            ("dataset", self.dataset.as_str().into()),
            ("temperature", self.temperature.into()),
            ("max_batch", self.max_batch.into()),
            ("max_new_tokens", self.max_new_tokens.into()),
            ("kv_blocks", self.kv_blocks.into()),
            ("kv_block_size", self.kv_block_size.into()),
            ("seed", self.seed.into()),
            ("artifacts_dir", self.artifacts_dir.as_str().into()),
            ("adaptive", self.adaptive.into()),
            ("ragged", self.ragged.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_json() {
        let c = Config {
            adaptive: true,
            ..Config::default()
        };
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.model, c.model);
        assert_eq!(c2.gamma, c.gamma);
        assert_eq!(c2.mode, Mode::Synthetic);
        assert!(c2.adaptive);
        assert!(!Config::default().adaptive);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(r#"{"gamma": 2}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.gamma, 2);
        assert_eq!(c.model, "qwen2-57b-a14b");
    }

    #[test]
    fn invalid_configs_rejected() {
        for bad in [
            r#"{"mode": "quantum"}"#,
            r#"{"gamma": 99}"#,
            r#"{"model": "not-a-model"}"#,
            r#"{"platform": "9xGPU-Z"}"#,
            r#"{"temperature": 7}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Config::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn engine_config_derivation() {
        let c = Config {
            max_batch: 20,
            ..Default::default()
        };
        let e = c.engine_config().unwrap();
        assert_eq!(e.scheduler.max_batch, 20);
        assert_eq!(e.buckets.max(), 16); // pow2 ≤ 20
        assert_eq!(e.gamma, c.gamma);
        assert!(e.control.is_none());
    }

    #[test]
    fn ragged_requires_adaptive_and_propagates() {
        // ragged without adaptive is a configuration error.
        let bad = Config {
            ragged: true,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // With adaptive, the flag reaches the controller config.
        let good = Config {
            adaptive: true,
            ragged: true,
            ..Default::default()
        };
        let ctl = good.engine_config().unwrap().control.unwrap();
        assert!(ctl.ragged);
        // Round-trips through JSON.
        let c2 = Config::from_json(&good.to_json()).unwrap();
        assert!(c2.ragged && c2.adaptive);
    }

    #[test]
    fn adaptive_flag_is_honored_by_engine_config() {
        let c = Config {
            adaptive: true,
            ..Default::default()
        };
        let e = c.engine_config().unwrap();
        let ctl = e.control.expect("adaptive must yield a controller config");
        assert!(matches!(
            ctl.policy,
            crate::control::PolicyKind::ModelGuided { .. }
        ));
        // α prior comes from the calibrated workload table.
        assert!(ctl.alpha_prior > 0.5 && ctl.alpha_prior < 1.0);
        // Adaptive + HLO is rejected outright.
        let bad = Config {
            adaptive: true,
            mode: Mode::Hlo,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        assert!(bad.engine_config().is_err());
    }
}
