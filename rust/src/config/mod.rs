//! Launcher configuration: a JSON file describing what to serve/simulate.
//!
//! Example (`examples/configs/private_serving.json`):
//! ```json
//! {
//!   "mode": "synthetic",
//!   "model": "qwen2-57b-a14b",
//!   "draft": "qwen2-0.5b",
//!   "platform": "2xGPU-A",
//!   "gamma": 4,
//!   "dataset": "humaneval",
//!   "temperature": 0.0,
//!   "max_batch": 32,
//!   "max_new_tokens": 128,
//!   "kv_blocks": 4096,
//!   "kv_block_size": 16,
//!   "seed": 0
//! }
//! ```

use crate::batching::Buckets;
use crate::control::{ControlConfig, CostModelSpec};
use crate::engine::EngineConfig;
use crate::kvcache::KvConfig;
use crate::scheduler::{AdmissionPolicyConfig, ClassAwareConfig, SchedulerConfig};
use crate::simulator::ExecSim;
use crate::util::json::Json;
use crate::workload::TenantClass;
use std::path::Path;

/// Which backend the launcher builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Paper-scale roofline-simulated serving.
    Synthetic,
    /// The tiny real model via PJRT artifacts.
    Hlo,
}

/// Full launcher configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub mode: Mode,
    pub model: String,
    pub draft: String,
    pub platform: String,
    pub gamma: usize,
    pub dataset: String,
    pub temperature: f64,
    pub max_batch: usize,
    pub max_new_tokens: usize,
    pub kv_blocks: usize,
    pub kv_block_size: usize,
    pub seed: u64,
    /// Artifacts directory (HLO mode).
    pub artifacts_dir: String,
    /// Enable the adaptive speculation control plane (synthetic mode):
    /// online model-guided γ/batch co-tuning instead of the fixed γ.
    pub adaptive: bool,
    /// Enable ragged rounds (per-sequence γᵢ refined from windowed
    /// per-sequence α̂ᵢ). Requires `adaptive`; the `--ragged` CLI flag
    /// sets both.
    pub ragged: bool,
    /// Multi-tenant SLO classes: a [`crate::workload::parse_tenants`]
    /// spec string (empty = classless serving). Setting it switches the
    /// admission scheduler to the class-aware policy.
    pub tenants: String,
    /// Mix-aware admission: the class-aware policy additionally consults
    /// the controller's priced regime test when composing the batch.
    /// Requires `adaptive` (the oracle) and a non-empty tenant table.
    pub mix_admission: bool,
    /// Arrival-trace CSV path (`t,prompt_len,output_len`) for the
    /// trace-replaying benches; empty = no trace.
    pub trace: String,
    /// Continuous batching: replace the lock-step round with the
    /// event-driven decode pipeline (chunked prefill + draft-ahead
    /// overlap + per-sequence round boundaries). Synthetic mode only —
    /// the pipeline's overlap pricing needs the virtual clock.
    pub continuous: bool,
    /// Per-op token budget for continuous-mode chunked prefill (each
    /// chunk op draws up to this many prompt tokens across the prefill
    /// queue). Only consulted when `continuous` is set. The default
    /// (512) sits at the weight/compute roofline crossover of the
    /// default MoE target, so chunk ops amortize expert weight reads
    /// like a bulk prefill.
    pub prefill_chunk: usize,
    /// Server trace recorder: write every submitted request as a
    /// `t,prompt_len,output_len` CSV row to this path on shutdown
    /// (`--record-trace PATH`); empty = off.
    pub record_trace: String,
    /// Static verify-expert budget: cap the experts the MoE target
    /// activates during *verify* forwards at this count (0 = off, the
    /// unbudgeted paper path). Cheaper verify, degraded acceptance for
    /// tokens routed outside the cap — the (γ, budget) trade.
    pub verify_budget: usize,
    /// Let the adaptive controller pick the verify budget jointly with γ
    /// from its measured acceptance-vs-budget curve. Requires `adaptive`;
    /// mutually exclusive with a static `verify_budget`.
    pub adaptive_budget: bool,
    /// Distributed serving: run the backend as a coordinator over
    /// `dist_workers` verify EP-rank workers plus one draft worker
    /// (`dist::DistBackend` on the in-process loopback transport).
    /// 0 = single-process (the default). Bit-identical output either
    /// way — the conformance suite pins it.
    pub dist_workers: usize,
    /// Draft replicas the distributed propose path stripes across
    /// (per-sequence home ranks, costs combined as `max + hop` like the
    /// verify fan). 1 (the default) is byte-identical to the
    /// single-process draft; only meaningful with `dist_workers > 0`.
    pub draft_workers: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            mode: Mode::Synthetic,
            model: "qwen2-57b-a14b".into(),
            draft: "qwen2-0.5b".into(),
            platform: "2xGPU-A".into(),
            gamma: 4,
            dataset: "humaneval".into(),
            temperature: 0.0,
            max_batch: 32,
            max_new_tokens: 128,
            kv_blocks: 4096,
            kv_block_size: 16,
            seed: 0,
            artifacts_dir: "artifacts".into(),
            adaptive: false,
            ragged: false,
            tenants: String::new(),
            mix_admission: false,
            trace: String::new(),
            continuous: false,
            prefill_chunk: 512,
            record_trace: String::new(),
            verify_budget: 0,
            adaptive_budget: false,
            dist_workers: 0,
            draft_workers: 1,
        }
    }
}

impl Config {
    pub fn from_json(j: &Json) -> anyhow::Result<Config> {
        let d = Config::default();
        let str_or = |key: &str, default: &str| -> String {
            j.get(key)
                .and_then(Json::as_str)
                .unwrap_or(default)
                .to_string()
        };
        let usize_or =
            |key: &str, default: usize| j.get(key).and_then(Json::as_usize).unwrap_or(default);
        let mode = match str_or("mode", "synthetic").as_str() {
            "synthetic" => Mode::Synthetic,
            "hlo" => Mode::Hlo,
            other => anyhow::bail!("unknown mode `{other}` (want synthetic|hlo)"),
        };
        let cfg = Config {
            mode,
            model: str_or("model", &d.model),
            draft: str_or("draft", &d.draft),
            platform: str_or("platform", &d.platform),
            gamma: usize_or("gamma", d.gamma),
            dataset: str_or("dataset", &d.dataset),
            temperature: j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0),
            max_batch: usize_or("max_batch", d.max_batch),
            max_new_tokens: usize_or("max_new_tokens", d.max_new_tokens),
            kv_blocks: usize_or("kv_blocks", d.kv_blocks),
            kv_block_size: usize_or("kv_block_size", d.kv_block_size),
            seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            artifacts_dir: str_or("artifacts_dir", &d.artifacts_dir),
            adaptive: j.get("adaptive").and_then(Json::as_bool).unwrap_or(false),
            ragged: j.get("ragged").and_then(Json::as_bool).unwrap_or(false),
            tenants: str_or("tenants", ""),
            mix_admission: j
                .get("mix_admission")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            trace: str_or("trace", ""),
            continuous: j.get("continuous").and_then(Json::as_bool).unwrap_or(false),
            prefill_chunk: usize_or("prefill_chunk", d.prefill_chunk),
            record_trace: str_or("record_trace", ""),
            verify_budget: usize_or("verify_budget", d.verify_budget),
            adaptive_budget: j
                .get("adaptive_budget")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            dist_workers: usize_or("dist_workers", d.dist_workers),
            draft_workers: usize_or("draft_workers", d.draft_workers),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> anyhow::Result<Config> {
        Config::from_json(&Json::parse_file(path)?)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.gamma <= 16, "gamma {} unreasonably large", self.gamma);
        anyhow::ensure!(self.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(
            self.kv_blocks >= 1 && self.kv_block_size >= 1,
            "invalid KV geometry"
        );
        anyhow::ensure!(
            (0.0..=2.0).contains(&self.temperature),
            "temperature out of range"
        );
        if self.mode == Mode::Synthetic {
            crate::arch::presets::by_name(&self.model)?;
            crate::arch::presets::by_name(&self.draft)?;
            crate::hardware::platform_by_name(&self.platform)?;
        }
        anyhow::ensure!(
            !(self.adaptive && self.mode == Mode::Hlo),
            "adaptive control requires synthetic mode (no calibrated cost model for \
             the HLO backend yet)"
        );
        anyhow::ensure!(
            !(self.ragged && !self.adaptive),
            "ragged speculation requires the adaptive control plane (use --ragged, \
             which implies --adaptive, or set both in the config file)"
        );
        // Surface tenant-spec typos at config time, not on the engine
        // thread (one parsing path: the same call engine_config uses).
        self.tenant_classes()?;
        anyhow::ensure!(
            !(self.mix_admission && self.tenants.is_empty()),
            "mix-aware admission needs a tenant table (--tenants)"
        );
        anyhow::ensure!(
            !(self.mix_admission && !self.adaptive),
            "mix-aware admission needs the adaptive control plane's priced \
             regime oracle (use --adaptive)"
        );
        anyhow::ensure!(
            self.prefill_chunk >= 1,
            "prefill_chunk must be >= 1 (it is the chunk size in tokens, \
             not an on/off switch — use `continuous` for that)"
        );
        anyhow::ensure!(
            !(self.continuous && self.mode == Mode::Hlo),
            "continuous batching requires synthetic mode (the pipeline's \
             overlap pricing needs the virtual clock)"
        );
        anyhow::ensure!(
            !(self.adaptive_budget && !self.adaptive),
            "adaptive verify budgeting needs the adaptive control plane \
             (use --adaptive-budget, which implies --adaptive, or set both \
             in the config file)"
        );
        anyhow::ensure!(
            !(self.adaptive_budget && self.verify_budget > 0),
            "pick one budget owner: a static --verify-budget or the \
             controller's --adaptive-budget, not both"
        );
        anyhow::ensure!(
            self.dist_workers <= 64,
            "dist_workers {} unreasonably large (max 64 verify ranks)",
            self.dist_workers
        );
        anyhow::ensure!(
            !(self.dist_workers > 0 && self.mode == Mode::Hlo),
            "distributed serving requires synthetic mode (the HLO backend \
             serves one host; socket workers are the planned lift)"
        );
        anyhow::ensure!(
            (1..=16).contains(&self.draft_workers),
            "draft_workers {} out of range (1..=16 draft replicas)",
            self.draft_workers
        );
        anyhow::ensure!(
            !(self.draft_workers > 1 && self.dist_workers == 0),
            "draft_workers > 1 stripes the distributed propose path; it \
             needs --dist-workers N (single-process has one draft)"
        );
        if self.verify_budget > 0 || self.adaptive_budget {
            anyhow::ensure!(
                self.mode == Mode::Synthetic,
                "verify budgeting requires synthetic mode (the HLO backend \
                 has no budgeted gate)"
            );
            let target = crate::arch::presets::by_name(&self.model)?;
            let platform = crate::hardware::platform_by_name(&self.platform)?;
            anyhow::ensure!(
                ExecSim::new(target, platform).moe_dims().is_some(),
                "verify budgeting caps *expert* activation — the target \
                 `{}` is dense",
                self.model
            );
        }
        Ok(())
    }

    /// The parsed tenant table (empty spec = no classes).
    pub fn tenant_classes(&self) -> anyhow::Result<Vec<TenantClass>> {
        if self.tenants.is_empty() {
            return Ok(Vec::new());
        }
        crate::workload::parse_tenants(&self.tenants)
    }

    /// The adaptive controller configuration this config implies:
    /// model-guided over the roofline simulator of the configured
    /// (model, draft, platform), with the workload-calibrated α as prior.
    /// `None` when `adaptive` is off.
    pub fn control_config(&self) -> anyhow::Result<Option<ControlConfig>> {
        if !self.adaptive {
            return Ok(None);
        }
        anyhow::ensure!(
            self.mode == Mode::Synthetic,
            "adaptive control requires synthetic mode"
        );
        let target = crate::arch::presets::by_name(&self.model)?;
        let draft = crate::arch::presets::by_name(&self.draft)?;
        let platform = crate::hardware::platform_by_name(&self.platform)?;
        let alpha = crate::workload::calibrated_alpha(
            crate::workload::model_family(&self.model),
            crate::workload::Dataset::by_name(&self.dataset)?,
            self.temperature,
            self.gamma.clamp(2, 4),
        );
        // Oracle matches the serve backend exactly: both the target and
        // the draft are priced on the full deployment platform (the same
        // ExecSim construction `serve` uses for the synthetic backend).
        let tsim = ExecSim::new(target, platform.clone());
        // Adaptive budgeting: the controller explores a small grid of
        // expert caps spanning the sparse regime — E/8 up to 3E/4 — and
        // keeps the unbudgeted arm as the always-present candidate. The
        // grid being non-empty is what makes the controller *own* the
        // budget (see `SpecController::owns_budget`).
        let budget_grid: Vec<usize> = if self.adaptive_budget {
            let (e, _k) = tsim.moe_dims().ok_or_else(|| {
                anyhow::anyhow!("adaptive verify budgeting needs a MoE target")
            })?;
            let mut grid: Vec<usize> = [e / 8, e / 4, e / 2, e * 3 / 4]
                .into_iter()
                .filter(|&b| b >= 1)
                .collect();
            grid.dedup();
            grid
        } else {
            Vec::new()
        };
        let dsim = ExecSim::new(draft, platform);
        Ok(Some(ControlConfig {
            alpha_prior: alpha,
            ragged: self.ragged,
            // Mix-aware admission reads per-sequence α̂ᵢ off the running
            // batch, so the controller tracks windows even without ragged
            // rounds.
            track_seq_alpha: self.ragged || self.mix_admission,
            budget_grid,
            ..ControlConfig::model_guided(CostModelSpec::roofline(tsim, dsim))
        }))
    }

    /// Derive the engine configuration (including the adaptive controller
    /// when `adaptive` is set — the flag is honored here, not just by the
    /// serve binary).
    pub fn engine_config(&self) -> anyhow::Result<EngineConfig> {
        let tenants = self.tenant_classes()?;
        let admission = if tenants.is_empty() {
            AdmissionPolicyConfig::Fifo
        } else if self.mix_admission {
            AdmissionPolicyConfig::ClassAware(ClassAwareConfig::mix_aware(1.05))
        } else {
            AdmissionPolicyConfig::ClassAware(ClassAwareConfig::default())
        };
        Ok(EngineConfig {
            gamma: self.gamma,
            kv: KvConfig {
                num_blocks: self.kv_blocks,
                block_size: self.kv_block_size,
            },
            scheduler: SchedulerConfig {
                max_batch: self.max_batch,
                admit_reserve_tokens: self.max_new_tokens.min(64),
                tpot_slo: None,
            },
            buckets: Buckets::pow2_up_to(self.max_batch.max(1)),
            seed: self.seed,
            control: self.control_config()?,
            gamma_overrides: std::collections::HashMap::new(),
            tenants,
            admission,
            pipeline: if self.continuous {
                crate::engine::PipelineConfig::full(self.prefill_chunk)
            } else {
                crate::engine::PipelineConfig::default()
            },
        })
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "mode",
                match self.mode {
                    Mode::Synthetic => "synthetic".into(),
                    Mode::Hlo => "hlo".into(),
                },
            ),
            ("model", self.model.as_str().into()),
            ("draft", self.draft.as_str().into()),
            ("platform", self.platform.as_str().into()),
            ("gamma", self.gamma.into()),
            ("dataset", self.dataset.as_str().into()),
            ("temperature", self.temperature.into()),
            ("max_batch", self.max_batch.into()),
            ("max_new_tokens", self.max_new_tokens.into()),
            ("kv_blocks", self.kv_blocks.into()),
            ("kv_block_size", self.kv_block_size.into()),
            ("seed", self.seed.into()),
            ("artifacts_dir", self.artifacts_dir.as_str().into()),
            ("adaptive", self.adaptive.into()),
            ("ragged", self.ragged.into()),
            ("tenants", self.tenants.as_str().into()),
            ("mix_admission", self.mix_admission.into()),
            ("trace", self.trace.as_str().into()),
            ("continuous", self.continuous.into()),
            ("prefill_chunk", self.prefill_chunk.into()),
            ("record_trace", self.record_trace.as_str().into()),
            ("verify_budget", self.verify_budget.into()),
            ("adaptive_budget", self.adaptive_budget.into()),
            ("dist_workers", self.dist_workers.into()),
            ("draft_workers", self.draft_workers.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_json() {
        let c = Config {
            adaptive: true,
            ..Config::default()
        };
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.model, c.model);
        assert_eq!(c2.gamma, c.gamma);
        assert_eq!(c2.mode, Mode::Synthetic);
        assert!(c2.adaptive);
        assert!(!Config::default().adaptive);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(r#"{"gamma": 2}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.gamma, 2);
        assert_eq!(c.model, "qwen2-57b-a14b");
    }

    #[test]
    fn invalid_configs_rejected() {
        for bad in [
            r#"{"mode": "quantum"}"#,
            r#"{"gamma": 99}"#,
            r#"{"model": "not-a-model"}"#,
            r#"{"platform": "9xGPU-Z"}"#,
            r#"{"temperature": 7}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Config::from_json(&j).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn engine_config_derivation() {
        let c = Config {
            max_batch: 20,
            ..Default::default()
        };
        let e = c.engine_config().unwrap();
        assert_eq!(e.scheduler.max_batch, 20);
        assert_eq!(e.buckets.max(), 16); // pow2 ≤ 20
        assert_eq!(e.gamma, c.gamma);
        assert!(e.control.is_none());
    }

    #[test]
    fn ragged_requires_adaptive_and_propagates() {
        // ragged without adaptive is a configuration error.
        let bad = Config {
            ragged: true,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // With adaptive, the flag reaches the controller config.
        let good = Config {
            adaptive: true,
            ragged: true,
            ..Default::default()
        };
        let ctl = good.engine_config().unwrap().control.unwrap();
        assert!(ctl.ragged);
        // Round-trips through JSON.
        let c2 = Config::from_json(&good.to_json()).unwrap();
        assert!(c2.ragged && c2.adaptive);
    }

    #[test]
    fn tenant_config_round_trips_and_drives_admission() {
        use crate::scheduler::AdmissionPolicyConfig;
        let spec = "chat:prio=2,share=0.2,ttft=0.5,alpha=0.9;bulk:share=0.8,alpha=0.5";
        let c = Config {
            adaptive: true,
            mix_admission: true,
            tenants: spec.into(),
            trace: "examples/traces/tiny_production.csv".into(),
            ..Config::default()
        };
        c.validate().unwrap();
        let e = c.engine_config().unwrap();
        assert_eq!(e.tenants.len(), 2);
        assert_eq!(e.tenants[0].name, "chat");
        assert!(matches!(
            e.admission,
            AdmissionPolicyConfig::ClassAware(ref cfg) if cfg.mix_speedup_floor.is_some()
        ));
        assert!(e.control.as_ref().unwrap().track_seq_alpha);
        // Round-trips through JSON.
        let c2 = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.tenants, spec);
        assert!(c2.mix_admission);
        assert_eq!(c2.trace, c.trace);
        // Tenants without mix: class-aware, α-blind.
        let blind = Config {
            tenants: "a;b".into(),
            ..Config::default()
        };
        let e = blind.engine_config().unwrap();
        assert!(matches!(
            e.admission,
            AdmissionPolicyConfig::ClassAware(ref cfg) if cfg.mix_speedup_floor.is_none()
        ));
        // No tenants: the bit-compatible FIFO baseline.
        assert!(matches!(
            Config::default().engine_config().unwrap().admission,
            AdmissionPolicyConfig::Fifo
        ));
        // Rejections: bad spec, mix without tenants, mix without adaptive.
        assert!(Config {
            tenants: "a:bogus=1".into(),
            ..Config::default()
        }
        .validate()
        .is_err());
        assert!(Config {
            mix_admission: true,
            ..Config::default()
        }
        .validate()
        .is_err());
        assert!(Config {
            mix_admission: true,
            tenants: "a;b".into(),
            ..Config::default()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn continuous_knobs_round_trip_and_reach_the_engine() {
        use crate::engine::PipelineConfig;
        // Default: lock-step pipeline config, exactly.
        let e = Config::default().engine_config().unwrap();
        assert_eq!(e.pipeline, PipelineConfig::default());
        assert!(!e.pipeline.continuous);
        // Continuous maps to the full pipeline with the chunk knob.
        let c = Config {
            continuous: true,
            prefill_chunk: 32,
            record_trace: "/tmp/rec.csv".into(),
            ..Config::default()
        };
        c.validate().unwrap();
        let e = c.engine_config().unwrap();
        assert_eq!(e.pipeline, PipelineConfig::full(32));
        assert!(e.pipeline.continuous && e.pipeline.draft_ahead);
        assert_eq!(e.pipeline.prefill_chunk, Some(32));
        // Round-trips through JSON.
        let c2 = Config::from_json(&c.to_json()).unwrap();
        assert!(c2.continuous);
        assert_eq!(c2.prefill_chunk, 32);
        assert_eq!(c2.record_trace, "/tmp/rec.csv");
        // Rejections: zero chunk, continuous on the wall-clock backend.
        assert!(Config {
            prefill_chunk: 0,
            ..Config::default()
        }
        .validate()
        .is_err());
        assert!(Config {
            continuous: true,
            mode: Mode::Hlo,
            ..Config::default()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn budget_knobs_round_trip_and_drive_the_controller_grid() {
        // Static budget round-trips; the controller grid stays empty
        // (the backend owns a fixed cap, the controller never moves it).
        let c = Config {
            verify_budget: 16,
            adaptive: true,
            ..Config::default()
        };
        c.validate().unwrap();
        let c2 = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.verify_budget, 16);
        assert!(!c2.adaptive_budget);
        let ctl = c.engine_config().unwrap().control.unwrap();
        assert!(ctl.budget_grid.is_empty());
        // Adaptive budgeting derives the sparse-regime grid from the
        // target's expert count (E = 64 for the default MoE preset).
        let a = Config {
            adaptive: true,
            adaptive_budget: true,
            ..Config::default()
        };
        a.validate().unwrap();
        let ctl = a.engine_config().unwrap().control.unwrap();
        assert_eq!(ctl.budget_grid, vec![8, 16, 32, 48]);
        let a2 = Config::from_json(&a.to_json()).unwrap();
        assert!(a2.adaptive_budget);
        // Rejections: adaptive_budget without adaptive, both owners at
        // once, budgeting a dense target.
        assert!(Config {
            adaptive_budget: true,
            ..Config::default()
        }
        .validate()
        .is_err());
        assert!(Config {
            adaptive: true,
            adaptive_budget: true,
            verify_budget: 8,
            ..Config::default()
        }
        .validate()
        .is_err());
        assert!(Config {
            verify_budget: 8,
            model: "qwen2-0.5b".into(),
            draft: "qwen2-0.5b".into(),
            ..Config::default()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn dist_workers_round_trips_and_validates() {
        // Default stays single-process.
        assert_eq!(Config::default().dist_workers, 0);
        // Round-trips through JSON.
        let c = Config {
            dist_workers: 2,
            ..Config::default()
        };
        c.validate().unwrap();
        let c2 = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.dist_workers, 2);
        // Missing key falls back to the default.
        let j = Json::parse(r#"{"gamma": 2}"#).unwrap();
        assert_eq!(Config::from_json(&j).unwrap().dist_workers, 0);
        // Rejections: absurd rank counts, distributed HLO serving.
        assert!(Config {
            dist_workers: 65,
            ..Config::default()
        }
        .validate()
        .is_err());
        assert!(Config {
            dist_workers: 2,
            mode: Mode::Hlo,
            ..Config::default()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn draft_workers_round_trips_and_validates() {
        // Default is one draft replica (the bit-exact configuration).
        assert_eq!(Config::default().draft_workers, 1);
        let c = Config {
            dist_workers: 2,
            draft_workers: 2,
            ..Config::default()
        };
        c.validate().unwrap();
        let c2 = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.draft_workers, 2);
        // Missing key falls back to the default.
        let j = Json::parse(r#"{"gamma": 2}"#).unwrap();
        assert_eq!(Config::from_json(&j).unwrap().draft_workers, 1);
        // Rejections: zero/absurd replica counts, striping without the
        // distributed engine.
        for (dist, draft) in [(2, 0), (2, 17), (0, 2)] {
            assert!(
                Config {
                    dist_workers: dist,
                    draft_workers: draft,
                    ..Config::default()
                }
                .validate()
                .is_err(),
                "dist={dist} draft={draft} should be rejected"
            );
        }
    }

    #[test]
    fn adaptive_flag_is_honored_by_engine_config() {
        let c = Config {
            adaptive: true,
            ..Default::default()
        };
        let e = c.engine_config().unwrap();
        let ctl = e.control.expect("adaptive must yield a controller config");
        assert!(matches!(
            ctl.policy,
            crate::control::PolicyKind::ModelGuided { .. }
        ));
        // α prior comes from the calibrated workload table.
        assert!(ctl.alpha_prior > 0.5 && ctl.alpha_prior < 1.0);
        // Adaptive + HLO is rejected outright.
        let bad = Config {
            adaptive: true,
            mode: Mode::Hlo,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        assert!(bad.engine_config().is_err());
    }
}
