//! Serving metrics: counters, log-bucketed latency histograms, and the
//! derived quantities the paper reports (T_AR, T_SD, σ, speedup, target
//! efficiency, TTFT/TPOT SLOs from §3.4).

use crate::util::stats::Running;
use std::collections::BTreeMap;

/// Log-bucketed histogram for latencies spanning µs..minutes.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds (seconds), geometric ladder.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    summary: Running,
}

impl Histogram {
    /// Buckets from 1 µs to ~1000 s, ×2 per step.
    pub fn new() -> Histogram {
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b < 1e3 {
            bounds.push(b);
            b *= 2.0;
        }
        let n = bounds.len();
        Histogram {
            bounds,
            counts: vec![0; n + 1],
            summary: Running::new(),
        }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.summary.push(v);
    }

    pub fn count(&self) -> u64 {
        self.summary.count()
    }

    pub fn mean(&self) -> f64 {
        self.summary.mean()
    }

    pub fn max(&self) -> f64 {
        self.summary.max()
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-quantile observation).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        let total = self.summary.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.summary.max()
                };
            }
        }
        self.summary.max()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Everything the engine records while serving, mirroring the quantities
/// the paper pulls from vLLM runtime logs (§4: T_D, T_T, T_reject, σ).
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    // --- request-level -----------------------------------------------------
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub ttft: Histogram2,
    pub tpot: Histogram2,
    pub e2e_latency: Histogram2,

    // --- SD round-level ----------------------------------------------------
    pub rounds: u64,
    pub draft_tokens_proposed: u64,
    pub draft_tokens_accepted: u64,
    /// Accumulated time per stage (the virtual or wall clock).
    pub time_draft: f64,
    pub time_verify: f64,
    pub time_reject: f64,
    pub time_prefill: f64,
    /// Draft time hidden under concurrent verify windows by the
    /// continuous engine's draft-ahead overlap (a subset of
    /// `time_draft`; zero on the lock-step path).
    pub time_draft_hidden: f64,
    /// Chunked-prefill ops executed by the continuous engine (zero when
    /// chunking is off).
    pub prefill_chunks: u64,
    /// Coordinator-side overhead (scheduling, sampling, bookkeeping).
    pub time_overhead: f64,
    /// Sum over rounds of the decode batch size (for mean batch size).
    pub batch_size_sum: u64,

    // --- per-tenant-class accounting ----------------------------------------
    /// Indexed by [`crate::batching::ClassId`]; grown on demand (single-
    /// class deployments carry one entry for the default class).
    pub class: Vec<ClassMetrics>,
}

/// Per-tenant-class serving metrics: latency distributions, SLO
/// attainment, and round participation (the multi-tenant observability
/// surface the server publishes per class).
#[derive(Debug, Clone, Default)]
pub struct ClassMetrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    /// Sequence-rounds: decode rounds this class's sequences sat in.
    pub seq_rounds: u64,
    pub preemptions: u64,
    pub ttft: Histogram2,
    pub tpot: Histogram2,
    /// SLO attainment counters (populated only when the class declares
    /// the corresponding SLO; totals count completions, met ≤ total).
    pub ttft_slo_met: u64,
    pub ttft_slo_total: u64,
    pub tpot_slo_met: u64,
    pub tpot_slo_total: u64,
}

impl ClassMetrics {
    /// Fraction of completions that met the TTFT SLO; `None` without one.
    pub fn ttft_attainment(&self) -> Option<f64> {
        (self.ttft_slo_total > 0).then(|| self.ttft_slo_met as f64 / self.ttft_slo_total as f64)
    }

    /// Fraction of completions that met the TPOT SLO; `None` without one.
    pub fn tpot_attainment(&self) -> Option<f64> {
        (self.tpot_slo_total > 0).then(|| self.tpot_slo_met as f64 / self.tpot_slo_total as f64)
    }
}

/// Small wrapper so EngineMetrics can derive Default cheaply.
#[derive(Debug, Clone)]
pub struct Histogram2(pub Histogram);

impl Default for Histogram2 {
    fn default() -> Self {
        Histogram2(Histogram::new())
    }
}

impl EngineMetrics {
    /// The class-metrics slot for `class`, growing the table on demand.
    pub fn class_mut(&mut self, class: usize) -> &mut ClassMetrics {
        if self.class.len() <= class {
            self.class.resize_with(class + 1, ClassMetrics::default);
        }
        &mut self.class[class]
    }

    /// σ as measured: generated tokens per sequence-round over the γ+1
    /// maximum (each of the `batch_size_sum` sequence-rounds could emit at
    /// most γ+1 tokens).
    pub fn sigma(&self, gamma: usize) -> f64 {
        if self.batch_size_sum == 0 || gamma == 0 {
            return 1.0;
        }
        let generated = self.tokens_generated as f64;
        generated / (self.batch_size_sum as f64 * (gamma + 1) as f64)
    }

    /// Empirical per-token acceptance rate α.
    pub fn acceptance_rate(&self) -> f64 {
        if self.draft_tokens_proposed == 0 {
            return 0.0;
        }
        self.draft_tokens_accepted as f64 / self.draft_tokens_proposed as f64
    }

    /// Total decode-path time (the paper's T_SD when γ>0, T_AR when γ=0).
    pub fn decode_time(&self) -> f64 {
        self.time_draft + self.time_verify + self.time_reject
    }

    /// Decode-path time on the critical path: total stage time minus the
    /// draft seconds the continuous pipeline hid under verify windows.
    /// Equals `decode_time()` on the lock-step path.
    pub fn pipeline_decode_time(&self) -> f64 {
        self.decode_time() - self.time_draft_hidden
    }

    pub fn total_time(&self) -> f64 {
        self.decode_time() + self.time_prefill + self.time_overhead
    }

    pub fn mean_batch(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.rounds as f64
        }
    }

    /// Decode throughput in tokens/second of (virtual or wall) clock.
    pub fn tokens_per_second(&self) -> f64 {
        let t = self.decode_time();
        if t <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / t
        }
    }

    /// Render a compact report block.
    pub fn report(&self, label: &str, gamma: usize) -> String {
        format!(
            "[{label}] requests={} tokens={} rounds={} σ={:.3} α={:.3} \
             mean_batch={:.1} decode={:.3}s (draft {:.3} verify {:.3} reject {:.3}) \
             prefill={:.3}s overhead={:.4}s throughput={:.1} tok/s\n\
             TTFT mean={:.4}s p99≈{:.4}s | TPOT mean={:.5}s p99≈{:.5}s",
            self.requests_completed,
            self.tokens_generated,
            self.rounds,
            self.sigma(gamma),
            self.acceptance_rate(),
            self.mean_batch(),
            self.decode_time(),
            self.time_draft,
            self.time_verify,
            self.time_reject,
            self.time_prefill,
            self.time_overhead,
            self.tokens_per_second(),
            self.ttft.0.mean(),
            self.ttft.0.quantile(0.99),
            self.tpot.0.mean(),
            self.tpot.0.quantile(0.99),
        )
    }
}

/// Named counters for ad-hoc instrumentation (failure injection tests use
/// these to observe retry/preemption paths).
#[derive(Debug, Clone, Default)]
pub struct Counters {
    map: BTreeMap<String, u64>,
}

impl Counters {
    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        *self.map.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.map.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.map.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        assert!(p50 >= 0.045 && p50 <= 0.07, "p50={p50}");
        assert!(h.quantile(1.0) >= 0.1);
        assert_eq!(Histogram::new().quantile(0.5), 0.0);
    }

    #[test]
    fn sigma_and_alpha() {
        let mut m = EngineMetrics::default();
        m.rounds = 10;
        m.batch_size_sum = 10; // batch of 1 per round
        m.tokens_generated = 36; // 3.6 per seq-round at γ=3 → σ=0.9
        m.draft_tokens_proposed = 30;
        m.draft_tokens_accepted = 26;
        assert!((m.sigma(3) - 0.9).abs() < 1e-12);
        assert!((m.acceptance_rate() - 26.0 / 30.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_and_batch() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 100;
        m.time_verify = 2.0;
        m.rounds = 4;
        m.batch_size_sum = 32;
        assert!((m.tokens_per_second() - 50.0).abs() < 1e-9);
        assert!((m.mean_batch() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn counters() {
        let mut c = Counters::default();
        c.inc("preemptions");
        c.add("preemptions", 2);
        assert_eq!(c.get("preemptions"), 3);
        assert_eq!(c.get("missing"), 0);
    }

    #[test]
    fn report_renders() {
        let m = EngineMetrics::default();
        let r = m.report("test", 3);
        assert!(r.contains("[test]"));
        assert!(r.contains("tok/s"));
    }

    #[test]
    fn class_metrics_grow_and_attain() {
        let mut m = EngineMetrics::default();
        assert!(m.class.is_empty());
        m.class_mut(2).requests_completed += 1;
        assert_eq!(m.class.len(), 3);
        assert_eq!(m.class[2].requests_completed, 1);
        assert_eq!(m.class[0].requests_completed, 0);
        let c = m.class_mut(0);
        assert_eq!(c.ttft_attainment(), None);
        c.ttft_slo_total = 4;
        c.ttft_slo_met = 3;
        c.tpot_slo_total = 2;
        c.tpot_slo_met = 2;
        assert_eq!(c.ttft_attainment(), Some(0.75));
        assert_eq!(c.tpot_attainment(), Some(1.0));
    }
}
