//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Two kinds of bench targets use this:
//! - **paper benches** (`fig*`, `table*`): run an experiment from
//!   [`crate::experiments`], print the paper-style table/series, write
//!   CSV + markdown under `results/`, and assert the qualitative shape
//!   claims so `cargo bench` doubles as a regression gate;
//! - **micro benches** (`micro_hotpath`): wall-clock timing of L3 hot
//!   paths with warmup and repetition statistics.

use crate::util::stats;
use std::path::{Path, PathBuf};
use std::time::Instant;

pub use crate::util::json::Json;

/// Where bench outputs land.
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("MOESD_RESULTS_DIR").unwrap_or_else(|_| "results".to_string());
    PathBuf::from(dir)
}

/// Write a text report file under results/ (creating directories).
pub fn write_report(name: &str, contents: &str) -> anyhow::Result<PathBuf> {
    let path = results_dir().join(name);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&path, contents)?;
    Ok(path)
}

/// One micro-bench metric as a JSON record (raw seconds plus the derived
/// ns/op the perf-trajectory tooling tracks). Built on the crate's shared
/// [`crate::util::json::Json`] value so bench output, configs, and the
/// artifact manifest all go through one writer.
pub fn bench_record_json(name: &str, secs: &[f64]) -> Json {
    Json::from_pairs(vec![
        ("name", Json::Str(name.to_string())),
        ("mean_s", Json::Num(stats::mean(secs))),
        ("p50_s", Json::Num(stats::median(secs))),
        ("min_s", Json::Num(stats::min(secs))),
        ("ns_per_op", Json::Num(stats::mean(secs) * 1e9)),
        ("n", Json::Num(secs.len() as f64)),
    ])
}

/// Write a pretty-printed JSON report under results/.
pub fn write_json_report(name: &str, json: &Json) -> anyhow::Result<PathBuf> {
    write_report(name, &json.to_pretty())
}

/// Outcome of comparing a fresh micro-bench run against the tracked
/// baseline (see [`compare_to_baseline`]).
#[derive(Debug, Clone, Default)]
pub struct BaselineReport {
    /// Metrics matched by name in both runs.
    pub compared: usize,
    /// Metrics present on only one side (renames, new benches).
    pub skipped: usize,
    /// Regressions over the warn band (fraction over baseline ns/op).
    pub warnings: Vec<String>,
    /// Regressions over the fail band.
    pub failures: Vec<String>,
}

impl BaselineReport {
    pub fn summary(&self) -> String {
        format!(
            "perf baseline: {} metrics compared, {} skipped, {} warning(s), {} failure(s)",
            self.compared,
            self.skipped,
            self.warnings.len(),
            self.failures.len()
        )
    }
}

/// Compare a fresh micro-bench JSON report against the tracked baseline
/// (`BENCH_hotpath.json`): metrics match by `name`, regress on
/// `ns_per_op`. A fresh value more than `warn_frac` over the baseline is
/// a warning, more than `fail_frac` a failure (improvements never flag —
/// refresh the baseline with `MOESD_WRITE_BASELINE=1` to bank them). An
/// unpopulated baseline (the skeleton the repo ships before the first
/// full run on a machine) compares nothing.
pub fn compare_to_baseline(
    current: &Json,
    baseline: &Json,
    warn_frac: f64,
    fail_frac: f64,
) -> BaselineReport {
    let mut report = BaselineReport::default();
    if baseline.get("populated").and_then(Json::as_bool) != Some(true) {
        return report;
    }
    let metric_map = |j: &Json| -> Vec<(String, f64)> {
        j.get("metrics")
            .and_then(Json::as_arr)
            .map(|arr| {
                arr.iter()
                    .filter_map(|m| {
                        Some((
                            m.get("name")?.as_str()?.to_string(),
                            m.get("ns_per_op")?.as_f64()?,
                        ))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let base = metric_map(baseline);
    let cur = metric_map(current);
    for (name, cur_ns) in &cur {
        let Some((_, base_ns)) = base.iter().find(|(n, _)| n == name) else {
            report.skipped += 1;
            continue;
        };
        if *base_ns <= 0.0 {
            report.skipped += 1;
            continue;
        }
        report.compared += 1;
        let frac = cur_ns / base_ns - 1.0;
        let line = format!(
            "{name}: {cur_ns:.0} ns/op vs baseline {base_ns:.0} ({:+.1}%)",
            frac * 100.0
        );
        if frac > fail_frac {
            report.failures.push(line);
        } else if frac > warn_frac {
            report.warnings.push(line);
        }
    }
    report.skipped += base.iter().filter(|(n, _)| !cur.iter().any(|(c, _)| c == n)).count();
    report
}

/// Micro-benchmark a closure: `warmup` unmeasured runs, then `reps`
/// measured runs. Returns per-rep seconds.
pub fn time_reps<F: FnMut()>(mut f: F, warmup: usize, reps: usize) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Format a micro-bench summary line.
pub fn summarize(name: &str, secs: &[f64]) -> String {
    format!(
        "{name:40} mean={:>10.3}µs  p50={:>10.3}µs  min={:>10.3}µs  n={}",
        stats::mean(secs) * 1e6,
        stats::median(secs) * 1e6,
        stats::min(secs) * 1e6,
        secs.len()
    )
}

/// A tiny assertion helper for bench shape checks: prints PASS/FAIL and
/// tracks overall status so the bench binary can exit nonzero.
pub struct ShapeChecks {
    failures: Vec<String>,
}

impl ShapeChecks {
    pub fn new() -> ShapeChecks {
        ShapeChecks {
            failures: Vec::new(),
        }
    }

    pub fn check(&mut self, label: &str, ok: bool) {
        if ok {
            println!("  shape-check PASS: {label}");
        } else {
            println!("  shape-check FAIL: {label}");
            self.failures.push(label.to_string());
        }
    }

    /// Exit-code aware finish: panics (bench failure) listing any failed
    /// shape checks.
    pub fn finish(self, bench_name: &str) {
        if !self.failures.is_empty() {
            panic!(
                "{bench_name}: {} shape check(s) failed: {:?}",
                self.failures.len(),
                self.failures
            );
        }
        println!("{bench_name}: all shape checks passed");
    }
}

impl Default for ShapeChecks {
    fn default() -> Self {
        Self::new()
    }
}

/// Standard bench banner.
pub fn banner(name: &str, paper_ref: &str) {
    println!("\n=== {name} — reproduces {paper_ref} ===");
}

/// Resolve a path relative to the repo root (benches run from the package
/// root already, but examples may be invoked elsewhere).
pub fn repo_path(rel: &str) -> PathBuf {
    let p = Path::new(rel);
    if p.exists() || p.is_absolute() {
        return p.to_path_buf();
    }
    // Fall back to CARGO_MANIFEST_DIR when running from another cwd.
    if let Ok(dir) = std::env::var("CARGO_MANIFEST_DIR") {
        let candidate = Path::new(&dir).join(rel);
        if candidate.exists() {
            return candidate;
        }
    }
    p.to_path_buf()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reps_counts() {
        let mut n = 0;
        let secs = time_reps(|| n += 1, 2, 5);
        assert_eq!(secs.len(), 5);
        assert_eq!(n, 7);
        assert!(secs.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn summarize_renders() {
        let s = summarize("kv_alloc", &[1e-6, 2e-6]);
        assert!(s.contains("kv_alloc"));
        assert!(s.contains("n=2"));
    }

    #[test]
    fn shape_checks_pass_path() {
        let mut c = ShapeChecks::new();
        c.check("ok", true);
        c.finish("test"); // must not panic
    }

    #[test]
    #[should_panic(expected = "shape check")]
    fn shape_checks_fail_path() {
        let mut c = ShapeChecks::new();
        c.check("bad", false);
        c.finish("test");
    }

    #[test]
    fn bench_record_json_fields_roundtrip() {
        let j = bench_record_json("kv_ops", &[1e-6, 3e-6]);
        let s = j.to_pretty();
        assert!(s.contains("\"name\": \"kv_ops\""));
        assert!(s.contains("\"ns_per_op\": 2000"));
        assert!(s.contains("\"n\": 2"));
        // The shared util::json writer emits parseable output.
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.req_f64("ns_per_op").unwrap(), 2000.0);
        assert_eq!(back.req_str("name").unwrap(), "kv_ops");
    }

    #[test]
    fn baseline_comparison_bands_and_skips() {
        let mk = |pairs: &[(&str, f64)], populated: bool| {
            Json::from_pairs(vec![
                ("populated", Json::Bool(populated)),
                (
                    "metrics",
                    Json::Arr(
                        pairs
                            .iter()
                            .map(|(n, ns)| {
                                Json::from_pairs(vec![
                                    ("name", Json::Str(n.to_string())),
                                    ("ns_per_op", Json::Num(*ns)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        let base = mk(&[("a", 100.0), ("b", 100.0), ("c", 100.0), ("gone", 50.0)], true);
        let cur = mk(&[("a", 104.0), ("b", 110.0), ("c", 140.0), ("new", 9.0)], true);
        let r = compare_to_baseline(&cur, &base, 0.05, 0.15);
        assert_eq!(r.compared, 3);
        assert_eq!(r.skipped, 2, "one renamed each way");
        assert_eq!(r.warnings.len(), 1, "{:?}", r.warnings); // b: +10%
        assert_eq!(r.failures.len(), 1, "{:?}", r.failures); // c: +40%
        assert!(r.failures[0].starts_with("c:"));
        assert!(r.summary().contains("3 metrics compared"));
        // Improvements never flag.
        let fast = mk(&[("a", 10.0), ("b", 10.0), ("c", 10.0)], true);
        let r = compare_to_baseline(&fast, &base, 0.05, 0.15);
        assert!(r.warnings.is_empty() && r.failures.is_empty());
        // The unpopulated skeleton compares nothing.
        let skel = mk(&[("a", 100.0)], false);
        let r = compare_to_baseline(&cur, &skel, 0.05, 0.15);
        assert_eq!(r.compared, 0);
    }

    #[test]
    fn write_report_creates_dirs() {
        let dir = std::env::temp_dir().join("moesd_benchlib_test");
        std::env::set_var("MOESD_RESULTS_DIR", &dir);
        let p = write_report("sub/report.txt", "hello").unwrap();
        assert!(p.exists());
        std::fs::remove_dir_all(&dir).ok();
        std::env::remove_var("MOESD_RESULTS_DIR");
    }
}
