//! # MoESD — speculative decoding for sparse Mixture-of-Experts serving
//!
//! A from-scratch reproduction of *"MoESD: Unveil Speculative Decoding's
//! Potential for Accelerating Sparse MoE"* (2025) as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! The crate is organized as a library (this file) plus a launcher binary
//! (`moesd`), runnable examples, and one benchmark target per table/figure
//! of the paper's evaluation. See `DESIGN.md` for the full system inventory
//! and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! Layer map:
//! - **L3 (this crate)** — request router, continuous batcher, paged KV cache,
//!   speculative-decoding scheduler, the adaptive speculation control plane
//!   ([`control`]: online γ / batch-ceiling co-tuning from measured target
//!   efficiency), metrics, the roofline GPU simulator — including
//!   expert-parallel sharding topologies ([`hardware`]:
//!   `Topology`/`ShardingSpec`) — and the paper's analytic speedup model +
//!   fitting.
//! - **L2 (python/compile/model.py)** — the JAX MoE transformer, AOT-lowered
//!   to HLO text loaded by [`runtime`].
//! - **L1 (python/compile/kernels/)** — Pallas MoE-FFN / decode-attention
//!   kernels lowered into the same HLO.
//!
//! New here? `docs/ARCHITECTURE.md` maps every module to the paper section
//! and equation it implements and walks one decode round through the stack.

pub mod arch;
pub mod batching;
pub mod benchlib;
pub mod config;
pub mod control;
pub mod dist;
pub mod engine;
pub mod experiments;
pub mod fit;
pub mod hardware;
pub mod kvcache;
pub mod metrics;
pub mod perfmodel;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod simulator;
pub mod spec;
pub mod testkit;
pub mod theory;
pub mod tokenizer;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
