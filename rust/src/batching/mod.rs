//! Request types and the continuous-batching queue.
//!
//! Requests arrive asynchronously; the batcher keeps a FIFO waiting queue
//! and a running set, and exposes shape *buckets* — the fixed batch sizes
//! the AOT-compiled HLO executables exist for. The scheduler admits
//! waiting requests whenever (a) a bucket has headroom and (b) the KV
//! manager can hold the prompt.

use crate::kvcache::SeqId;
use std::collections::VecDeque;

/// Tenant/SLO class handle: an index into the launcher's
/// [`crate::workload::TenantClass`] table. Class 0 is the implicit default
/// class of single-tenant deployments — every pre-multi-tenant constructor
/// uses it, so the classless serving path is unchanged.
pub type ClassId = usize;

/// The default class untagged requests belong to.
pub const DEFAULT_CLASS: ClassId = 0;

/// Sampling configuration for a request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    pub temperature: f64,
    /// Stop after this many generated tokens.
    pub max_new_tokens: usize,
    /// Optional stop token.
    pub eos_token: Option<u32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            max_new_tokens: 64,
            eos_token: None,
        }
    }
}

/// An inference request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: SeqId,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    /// Arrival time on the engine clock (seconds).
    pub arrival: f64,
    /// Tenant/SLO class ([`DEFAULT_CLASS`] for untagged requests).
    pub class: ClassId,
}

impl Request {
    /// Tag this request with a tenant class (builder-style, so existing
    /// `Request { .. }` construction sites stay untouched).
    pub fn with_class(mut self, class: ClassId) -> Request {
        self.class = class;
        self
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: SeqId,
    pub tokens: Vec<u32>,
    /// Engine-clock timestamps for SLO accounting.
    pub arrival: f64,
    pub first_token_at: f64,
    pub finished_at: f64,
    /// SD rounds this sequence participated in.
    pub rounds: u64,
    /// Tenant/SLO class the request belonged to.
    pub class: ClassId,
}

impl Completion {
    pub fn ttft(&self) -> f64 {
        self.first_token_at - self.arrival
    }

    pub fn tpot(&self) -> f64 {
        if self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.finished_at - self.first_token_at) / (self.tokens.len() - 1) as f64
    }
}

/// The waiting queue plus admission bookkeeping.
#[derive(Debug, Default)]
pub struct RequestQueue {
    waiting: VecDeque<Request>,
    /// Total requests ever enqueued (id uniqueness checks).
    submitted: u64,
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    pub fn push(&mut self, req: Request) {
        assert!(!req.prompt.is_empty(), "empty prompt");
        self.waiting.push_back(req);
        self.submitted += 1;
    }

    pub fn len(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_empty(&self) -> bool {
        self.waiting.is_empty()
    }

    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Peek at the head without removing (admission checks capacity first).
    pub fn peek(&self) -> Option<&Request> {
        self.waiting.front()
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.waiting.pop_front()
    }

    /// Requeue at the *front* (preemption putback keeps FIFO fairness).
    pub fn push_front(&mut self, req: Request) {
        self.waiting.push_front(req);
    }

    /// Iterate waiting requests in queue (arrival) order. Class-aware
    /// admission scans this to build its per-class logical queues; the
    /// physical queue stays one arrival-ordered deque so FIFO admission is
    /// untouched and per-class FIFO order falls out of the scan order.
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.waiting.iter()
    }

    /// Remove and return the request at queue position `idx` (0 = head).
    /// O(n) middle removal — admission runs once per decode round over a
    /// modest queue, not on a per-token path.
    pub fn remove_at(&mut self, idx: usize) -> Option<Request> {
        self.waiting.remove(idx)
    }
}

/// Shape buckets: batch sizes with compiled executables. Decode batches are
/// padded up to the nearest bucket (smaller buckets waste less compute but
/// cost more compilations).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Buckets {
    sizes: Vec<usize>,
}

impl Buckets {
    pub fn new(mut sizes: Vec<usize>) -> Buckets {
        assert!(!sizes.is_empty(), "need at least one bucket");
        sizes.sort_unstable();
        sizes.dedup();
        assert!(sizes[0] >= 1);
        Buckets { sizes }
    }

    /// Powers of two up to `max`.
    pub fn pow2_up_to(max: usize) -> Buckets {
        let mut sizes = Vec::new();
        let mut b = 1;
        while b <= max {
            sizes.push(b);
            b *= 2;
        }
        Buckets::new(sizes)
    }

    pub fn max(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Smallest bucket that fits `n` sequences, or the largest bucket if
    /// none does (caller must then split the batch).
    pub fn fit(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        self.max()
    }

    /// Padding waste for batching `n` sequences into the fitted bucket.
    pub fn waste(&self, n: usize) -> usize {
        self.fit(n).saturating_sub(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: SeqId) -> Request {
        Request {
            id,
            prompt: vec![1, 2, 3],
            params: SamplingParams::default(),
            arrival: 0.0,
            class: DEFAULT_CLASS,
        }
    }

    #[test]
    fn queue_fifo_and_putback() {
        let mut q = RequestQueue::new();
        q.push(req(1));
        q.push(req(2));
        assert_eq!(q.len(), 2);
        let r = q.pop().unwrap();
        assert_eq!(r.id, 1);
        q.push_front(r); // preemption
        assert_eq!(q.peek().unwrap().id, 1);
        assert_eq!(q.submitted(), 2);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn empty_prompt_rejected() {
        let mut q = RequestQueue::new();
        q.push(Request {
            id: 1,
            prompt: vec![],
            params: SamplingParams::default(),
            arrival: 0.0,
            class: DEFAULT_CLASS,
        });
    }

    #[test]
    fn buckets_fit_and_waste() {
        let b = Buckets::pow2_up_to(16);
        assert_eq!(b.sizes(), &[1, 2, 4, 8, 16]);
        assert_eq!(b.fit(1), 1);
        assert_eq!(b.fit(3), 4);
        assert_eq!(b.fit(16), 16);
        assert_eq!(b.fit(20), 16); // overflow → caller splits
        assert_eq!(b.waste(5), 3);
        assert_eq!(b.waste(8), 0);
    }

    #[test]
    fn buckets_dedupe_and_sort() {
        let b = Buckets::new(vec![8, 2, 2, 4]);
        assert_eq!(b.sizes(), &[2, 4, 8]);
        assert_eq!(b.max(), 8);
    }

    #[test]
    fn queue_iter_and_middle_removal() {
        let mut q = RequestQueue::new();
        for id in 1..=4 {
            q.push(req(id).with_class((id % 2) as ClassId));
        }
        let ids: Vec<SeqId> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4]);
        // Remove from the middle; remaining order is preserved.
        let r = q.remove_at(1).unwrap();
        assert_eq!(r.id, 2);
        assert_eq!(r.class, 0);
        let ids: Vec<SeqId> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3, 4]);
        assert!(q.remove_at(10).is_none());
        // Untagged requests are class 0; with_class retags.
        assert_eq!(req(9).class, DEFAULT_CLASS);
        assert_eq!(req(9).with_class(3).class, 3);
    }

    #[test]
    fn completion_slo_math() {
        let c = Completion {
            id: 1,
            tokens: vec![1, 2, 3, 4, 5],
            arrival: 10.0,
            first_token_at: 10.5,
            finished_at: 12.5,
            rounds: 2,
            class: DEFAULT_CLASS,
        };
        assert!((c.ttft() - 0.5).abs() < 1e-12);
        assert!((c.tpot() - 0.5).abs() < 1e-12);
    }
}
