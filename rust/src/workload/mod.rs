//! Workload generation: synthetic stand-ins for the paper's HumanEval and
//! MT-Bench evaluations (see DESIGN.md §Substitutions).
//!
//! SD performance depends on the workload only through (a) prompt/output
//! length distributions and (b) the draft acceptance behavior. Both are
//! parameterized directly from the paper:
//!
//! - prompt lengths: tokenized prompts span 38–391 tokens for HumanEval and
//!   5–356 for MT-Bench (§4 "Models and datasets");
//! - acceptance: σ per (dataset, temperature, γ) from Tables 1–2, inverted
//!   through Eq. 5 to the α that drives the synthetic backend. Code at
//!   temperature 0 is most predictable (σ up to 0.95), conversation at
//!   temperature 1 least (σ down to 0.35) — exactly the paper's spread.

use crate::batching::{Request, SamplingParams};
use crate::theory;
use crate::util::rng::Rng;

/// The two evaluation datasets the paper uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    HumanEval,
    MtBench,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::HumanEval => "humaneval",
            Dataset::MtBench => "mtbench",
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Dataset> {
        match name {
            "humaneval" => Ok(Dataset::HumanEval),
            "mtbench" => Ok(Dataset::MtBench),
            other => anyhow::bail!("unknown dataset `{other}`"),
        }
    }

    /// Tokenized-prompt length range reported by the paper.
    pub fn prompt_range(&self) -> (usize, usize) {
        match self {
            Dataset::HumanEval => (38, 391),
            Dataset::MtBench => (5, 356),
        }
    }
}

/// σ values per (model, dataset, temperature, γ) transcribed from the
/// paper's Table 1 (2×GPU-A, the calibration platform). γ is indexed 2..4.
pub fn paper_sigma(model: &str, dataset: Dataset, temp: f64, gamma: usize) -> f64 {
    let hot = temp < 0.5;
    let idx = gamma.clamp(2, 4) - 2;
    // Rows: [γ=2, γ=3, γ=4].
    let table: [f64; 3] = match (model, dataset, hot) {
        ("qwen2", Dataset::HumanEval, true) => [0.94, 0.93, 0.91],
        ("qwen2", Dataset::HumanEval, false) => [0.83, 0.73, 0.67],
        ("qwen2", Dataset::MtBench, true) => [0.71, 0.62, 0.55],
        ("qwen2", Dataset::MtBench, false) => [0.68, 0.57, 0.48],
        ("mixtral", Dataset::HumanEval, true) => [0.78, 0.66, 0.58],
        ("mixtral", Dataset::HumanEval, false) => [0.61, 0.46, 0.39],
        ("mixtral", Dataset::MtBench, true) => [0.61, 0.46, 0.39],
        ("mixtral", Dataset::MtBench, false) => [0.53, 0.43, 0.35],
        // Dense comparison (OPT-30B with OPT-350M): mid-range acceptance.
        ("opt", Dataset::HumanEval, true) => [0.85, 0.80, 0.75],
        ("opt", Dataset::HumanEval, false) => [0.70, 0.62, 0.55],
        ("opt", Dataset::MtBench, true) => [0.68, 0.60, 0.52],
        ("opt", Dataset::MtBench, false) => [0.60, 0.50, 0.44],
        _ => [0.75, 0.65, 0.55],
    };
    table[idx]
}

/// α calibrated so Eq. 5 reproduces the paper's σ at the given γ.
pub fn calibrated_alpha(model: &str, dataset: Dataset, temp: f64, gamma: usize) -> f64 {
    let sigma = paper_sigma(model, dataset, temp, gamma);
    theory::alpha_from_sigma(sigma, gamma.clamp(2, 4))
}

/// Map a model preset name to its [`paper_sigma`] calibration family
/// ("qwen2" / "mixtral" / "opt"; anything else hits the table's default
/// row). Shared by the launcher and config so the mapping lives in one
/// place.
pub fn model_family(model_name: &str) -> &'static str {
    if model_name.starts_with("qwen2") {
        "qwen2"
    } else if model_name.starts_with("mixtral") {
        "mixtral"
    } else if model_name.starts_with("opt") {
        "opt"
    } else {
        "generic"
    }
}

/// A workload profile: how requests look and arrive.
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub dataset: Dataset,
    pub temperature: f64,
    /// Output budget per request (the paper decodes fixed-length windows).
    pub max_new_tokens: usize,
    /// Mean arrival rate (requests/second); `None` = all at t=0 (the
    /// paper's batch experiments).
    pub arrival_rate: Option<f64>,
}

impl WorkloadProfile {
    pub fn batch(dataset: Dataset, temperature: f64, max_new_tokens: usize) -> WorkloadProfile {
        WorkloadProfile {
            dataset,
            temperature,
            max_new_tokens,
            arrival_rate: None,
        }
    }

    /// Draw one prompt length: log-normal shaped into the dataset's range
    /// (long-tailed, as real prompt-length histograms are).
    pub fn sample_prompt_len(&self, rng: &mut Rng) -> usize {
        let (lo, hi) = self.dataset.prompt_range();
        let mid = ((lo + hi) / 2) as f64;
        let raw = rng.lognormal(mid.ln() * 0.92, 0.45);
        (raw as usize).clamp(lo, hi)
    }

    /// Generate `n` requests with ids `id0..id0+n`, sorted by arrival.
    pub fn generate(&self, n: usize, id0: u64, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed, 0x77);
        let mut t = 0.0f64;
        (0..n)
            .map(|i| {
                let arrival = match self.arrival_rate {
                    None => 0.0,
                    Some(rate) => {
                        t += rng.exponential(rate);
                        t
                    }
                };
                let len = self.sample_prompt_len(&mut rng);
                Request {
                    id: id0 + i as u64,
                    prompt: (0..len as u32).map(|p| p % 251).collect(),
                    params: SamplingParams {
                        temperature: self.temperature,
                        max_new_tokens: self.max_new_tokens,
                        eos_token: None,
                    },
                    arrival,
                }
            })
            .collect()
    }
}

/// One phase of a non-stationary arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RampPhase {
    /// Mean arrival rate during the phase (requests/second).
    pub rate: f64,
    /// Phase length (seconds).
    pub duration: f64,
}

/// Piecewise-stationary Poisson arrivals — the shifting-traffic workload
/// the adaptive control plane's soak test drives through the engine
/// (`tests/integration_control.rs::traffic_ramp_soak_...`). Each phase
/// draws exponential inter-arrivals at its own rate, so a ramp like
/// 4 → 256 req/s sweeps the engine through the full §3.1 batch-size
/// regime (memory-bound SD paradise up to compute-bound AR territory)
/// in one open-loop run.
#[derive(Debug, Clone)]
pub struct TrafficRamp {
    pub phases: Vec<RampPhase>,
}

impl TrafficRamp {
    pub fn new(phases: Vec<RampPhase>) -> TrafficRamp {
        assert!(!phases.is_empty(), "ramp needs at least one phase");
        for p in &phases {
            assert!(p.rate > 0.0 && p.duration > 0.0, "invalid phase {p:?}");
        }
        TrafficRamp { phases }
    }

    /// Geometric ramp: `n` phases of `duration` seconds each, starting at
    /// `rate0` requests/second and multiplying by `factor` per phase.
    pub fn geometric(rate0: f64, factor: f64, n: usize, duration: f64) -> TrafficRamp {
        assert!(n >= 1 && rate0 > 0.0 && factor > 0.0);
        let mut phases = Vec::with_capacity(n);
        let mut rate = rate0;
        for _ in 0..n {
            phases.push(RampPhase { rate, duration });
            rate *= factor;
        }
        TrafficRamp::new(phases)
    }

    pub fn total_duration(&self) -> f64 {
        self.phases.iter().map(|p| p.duration).sum()
    }

    /// Index of the phase containing time `t` (clamped to the last phase).
    pub fn phase_at(&self, t: f64) -> usize {
        let mut acc = 0.0;
        for (i, p) in self.phases.iter().enumerate() {
            acc += p.duration;
            if t < acc {
                return i;
            }
        }
        self.phases.len() - 1
    }

    /// Generate the ramp's requests (ids `id0..`), sorted by arrival.
    /// Prompt lengths and sampling parameters come from `profile` (its
    /// own `arrival_rate` is ignored — the ramp owns arrival times).
    pub fn generate(&self, profile: &WorkloadProfile, id0: u64, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed, 0x7a);
        let mut out = Vec::new();
        let mut id = id0;
        let mut phase_start = 0.0;
        for phase in &self.phases {
            let mut t = phase_start;
            loop {
                t += rng.exponential(phase.rate);
                if t >= phase_start + phase.duration {
                    break;
                }
                let len = profile.sample_prompt_len(&mut rng);
                out.push(Request {
                    id,
                    prompt: (0..len as u32).map(|p| p % 251).collect(),
                    params: SamplingParams {
                        temperature: profile.temperature,
                        max_new_tokens: profile.max_new_tokens,
                        eos_token: None,
                    },
                    arrival: t,
                });
                id += 1;
            }
            phase_start += phase.duration;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_lengths_in_paper_ranges() {
        let mut rng = Rng::seeded(1);
        for ds in [Dataset::HumanEval, Dataset::MtBench] {
            let p = WorkloadProfile::batch(ds, 0.0, 32);
            let (lo, hi) = ds.prompt_range();
            for _ in 0..500 {
                let l = p.sample_prompt_len(&mut rng);
                assert!(l >= lo && l <= hi, "{}: {l} outside [{lo},{hi}]", ds.name());
            }
        }
    }

    #[test]
    fn sigma_table_monotonicities() {
        // σ decreases with γ (harder to keep a long chain accepted)…
        for &gamma in &[2usize, 3] {
            assert!(
                paper_sigma("qwen2", Dataset::HumanEval, 0.0, gamma)
                    >= paper_sigma("qwen2", Dataset::HumanEval, 0.0, gamma + 1)
            );
        }
        // …and with temperature (more randomness), and from code → chat.
        assert!(
            paper_sigma("qwen2", Dataset::HumanEval, 0.0, 3)
                > paper_sigma("qwen2", Dataset::HumanEval, 1.0, 3)
        );
        assert!(
            paper_sigma("qwen2", Dataset::HumanEval, 0.0, 3)
                > paper_sigma("qwen2", Dataset::MtBench, 0.0, 3)
        );
    }

    #[test]
    fn calibrated_alpha_reproduces_sigma() {
        for &gamma in &[2usize, 3, 4] {
            for ds in [Dataset::HumanEval, Dataset::MtBench] {
                for &temp in &[0.0, 1.0] {
                    let alpha = calibrated_alpha("qwen2", ds, temp, gamma);
                    let sigma_back = theory::sigma_from_alpha(alpha, gamma);
                    let sigma_want = paper_sigma("qwen2", ds, temp, gamma);
                    assert!(
                        (sigma_back - sigma_want).abs() < 1e-6,
                        "γ={gamma} {}: {sigma_back} vs {sigma_want}",
                        ds.name()
                    );
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        let p = WorkloadProfile {
            dataset: Dataset::MtBench,
            temperature: 1.0,
            max_new_tokens: 64,
            arrival_rate: Some(4.0),
        };
        let a = p.generate(50, 0, 9);
        let b = p.generate(50, 0, 9);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        // Batch profile arrives at t=0.
        let batch = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 8).generate(10, 0, 1);
        assert!(batch.iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn ramp_phase_counts_track_rates() {
        let ramp = TrafficRamp::geometric(10.0, 4.0, 3, 20.0); // 10, 40, 160 req/s
        let profile = WorkloadProfile::batch(Dataset::MtBench, 0.0, 16);
        let reqs = ramp.generate(&profile, 0, 5);
        let mut counts = [0usize; 3];
        for r in &reqs {
            counts[ramp.phase_at(r.arrival)] += 1;
        }
        // Expected counts: rate × duration = 200, 800, 3200 (±20%).
        for (i, &want) in [200.0f64, 800.0, 3200.0].iter().enumerate() {
            let got = counts[i] as f64;
            assert!(
                (got - want).abs() / want < 0.2,
                "phase {i}: {got} arrivals vs expected {want}"
            );
        }
        // Sorted and inside the ramp window.
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(reqs.last().unwrap().arrival < ramp.total_duration());
    }

    #[test]
    fn ramp_generation_is_deterministic() {
        let ramp = TrafficRamp::new(vec![
            RampPhase {
                rate: 5.0,
                duration: 10.0,
            },
            RampPhase {
                rate: 50.0,
                duration: 10.0,
            },
        ]);
        let profile = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 8);
        let a = ramp.generate(&profile, 0, 9);
        let b = ramp.generate(&profile, 0, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn ramp_phase_at_boundaries() {
        let ramp = TrafficRamp::geometric(1.0, 2.0, 3, 10.0);
        assert_eq!(ramp.phase_at(0.0), 0);
        assert_eq!(ramp.phase_at(9.99), 0);
        assert_eq!(ramp.phase_at(10.0), 1);
        assert_eq!(ramp.phase_at(25.0), 2);
        assert_eq!(ramp.phase_at(1e9), 2); // clamped past the end
        assert_eq!(ramp.total_duration(), 30.0);
    }

    #[test]
    #[should_panic(expected = "invalid phase")]
    fn ramp_rejects_nonpositive_rate() {
        TrafficRamp::new(vec![RampPhase {
            rate: 0.0,
            duration: 1.0,
        }]);
    }
}
