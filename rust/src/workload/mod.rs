//! Workload generation: synthetic stand-ins for the paper's HumanEval and
//! MT-Bench evaluations (see DESIGN.md §Substitutions).
//!
//! SD performance depends on the workload only through (a) prompt/output
//! length distributions and (b) the draft acceptance behavior. Both are
//! parameterized directly from the paper:
//!
//! - prompt lengths: tokenized prompts span 38–391 tokens for HumanEval and
//!   5–356 for MT-Bench (§4 "Models and datasets");
//! - acceptance: σ per (dataset, temperature, γ) from Tables 1–2, inverted
//!   through Eq. 5 to the α that drives the synthetic backend. Code at
//!   temperature 0 is most predictable (σ up to 0.95), conversation at
//!   temperature 1 least (σ down to 0.35) — exactly the paper's spread.

use crate::batching::{ClassId, Request, SamplingParams, DEFAULT_CLASS};
use crate::theory;
use crate::util::rng::Rng;

/// The two evaluation datasets the paper uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    HumanEval,
    MtBench,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::HumanEval => "humaneval",
            Dataset::MtBench => "mtbench",
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Dataset> {
        match name {
            "humaneval" => Ok(Dataset::HumanEval),
            "mtbench" => Ok(Dataset::MtBench),
            other => anyhow::bail!("unknown dataset `{other}`"),
        }
    }

    /// Tokenized-prompt length range reported by the paper.
    pub fn prompt_range(&self) -> (usize, usize) {
        match self {
            Dataset::HumanEval => (38, 391),
            Dataset::MtBench => (5, 356),
        }
    }
}

/// σ values per (model, dataset, temperature, γ) transcribed from the
/// paper's Table 1 (2×GPU-A, the calibration platform). γ is indexed 2..4.
pub fn paper_sigma(model: &str, dataset: Dataset, temp: f64, gamma: usize) -> f64 {
    let hot = temp < 0.5;
    let idx = gamma.clamp(2, 4) - 2;
    // Rows: [γ=2, γ=3, γ=4].
    let table: [f64; 3] = match (model, dataset, hot) {
        ("qwen2", Dataset::HumanEval, true) => [0.94, 0.93, 0.91],
        ("qwen2", Dataset::HumanEval, false) => [0.83, 0.73, 0.67],
        ("qwen2", Dataset::MtBench, true) => [0.71, 0.62, 0.55],
        ("qwen2", Dataset::MtBench, false) => [0.68, 0.57, 0.48],
        ("mixtral", Dataset::HumanEval, true) => [0.78, 0.66, 0.58],
        ("mixtral", Dataset::HumanEval, false) => [0.61, 0.46, 0.39],
        ("mixtral", Dataset::MtBench, true) => [0.61, 0.46, 0.39],
        ("mixtral", Dataset::MtBench, false) => [0.53, 0.43, 0.35],
        // Dense comparison (OPT-30B with OPT-350M): mid-range acceptance.
        ("opt", Dataset::HumanEval, true) => [0.85, 0.80, 0.75],
        ("opt", Dataset::HumanEval, false) => [0.70, 0.62, 0.55],
        ("opt", Dataset::MtBench, true) => [0.68, 0.60, 0.52],
        ("opt", Dataset::MtBench, false) => [0.60, 0.50, 0.44],
        _ => [0.75, 0.65, 0.55],
    };
    table[idx]
}

/// α calibrated so Eq. 5 reproduces the paper's σ at the given γ.
pub fn calibrated_alpha(model: &str, dataset: Dataset, temp: f64, gamma: usize) -> f64 {
    let sigma = paper_sigma(model, dataset, temp, gamma);
    theory::alpha_from_sigma(sigma, gamma.clamp(2, 4))
}

/// Map a model preset name to its [`paper_sigma`] calibration family
/// ("qwen2" / "mixtral" / "opt"; anything else hits the table's default
/// row). Shared by the launcher and config so the mapping lives in one
/// place.
pub fn model_family(model_name: &str) -> &'static str {
    if model_name.starts_with("qwen2") {
        "qwen2"
    } else if model_name.starts_with("mixtral") {
        "mixtral"
    } else if model_name.starts_with("opt") {
        "opt"
    } else {
        "generic"
    }
}

/// A workload profile: how requests look and arrive.
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub dataset: Dataset,
    pub temperature: f64,
    /// Output budget per request (the paper decodes fixed-length windows).
    pub max_new_tokens: usize,
    /// Mean arrival rate (requests/second); `None` = all at t=0 (the
    /// paper's batch experiments).
    pub arrival_rate: Option<f64>,
}

impl WorkloadProfile {
    pub fn batch(dataset: Dataset, temperature: f64, max_new_tokens: usize) -> WorkloadProfile {
        WorkloadProfile {
            dataset,
            temperature,
            max_new_tokens,
            arrival_rate: None,
        }
    }

    /// Draw one prompt length: log-normal shaped into the dataset's range
    /// (long-tailed, as real prompt-length histograms are).
    pub fn sample_prompt_len(&self, rng: &mut Rng) -> usize {
        let (lo, hi) = self.dataset.prompt_range();
        let mid = ((lo + hi) / 2) as f64;
        let raw = rng.lognormal(mid.ln() * 0.92, 0.45);
        (raw as usize).clamp(lo, hi)
    }

    /// Generate `n` requests with ids `id0..id0+n`, sorted by arrival.
    pub fn generate(&self, n: usize, id0: u64, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed, 0x77);
        let mut t = 0.0f64;
        (0..n)
            .map(|i| {
                let arrival = match self.arrival_rate {
                    None => 0.0,
                    Some(rate) => {
                        t += rng.exponential(rate);
                        t
                    }
                };
                let len = self.sample_prompt_len(&mut rng);
                Request {
                    id: id0 + i as u64,
                    prompt: (0..len as u32).map(|p| p % 251).collect(),
                    params: SamplingParams {
                        temperature: self.temperature,
                        max_new_tokens: self.max_new_tokens,
                        eos_token: None,
                    },
                    arrival,
                    class: DEFAULT_CLASS,
                }
            })
            .collect()
    }
}

/// One phase of a non-stationary arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RampPhase {
    /// Mean arrival rate during the phase (requests/second).
    pub rate: f64,
    /// Phase length (seconds).
    pub duration: f64,
}

/// Piecewise-stationary Poisson arrivals — the shifting-traffic workload
/// the adaptive control plane's soak test drives through the engine
/// (`tests/integration_control.rs::traffic_ramp_soak_...`). Each phase
/// draws exponential inter-arrivals at its own rate, so a ramp like
/// 4 → 256 req/s sweeps the engine through the full §3.1 batch-size
/// regime (memory-bound SD paradise up to compute-bound AR territory)
/// in one open-loop run.
#[derive(Debug, Clone)]
pub struct TrafficRamp {
    pub phases: Vec<RampPhase>,
}

impl TrafficRamp {
    pub fn new(phases: Vec<RampPhase>) -> TrafficRamp {
        assert!(!phases.is_empty(), "ramp needs at least one phase");
        for p in &phases {
            assert!(p.rate > 0.0 && p.duration > 0.0, "invalid phase {p:?}");
        }
        TrafficRamp { phases }
    }

    /// Geometric ramp: `n` phases of `duration` seconds each, starting at
    /// `rate0` requests/second and multiplying by `factor` per phase.
    pub fn geometric(rate0: f64, factor: f64, n: usize, duration: f64) -> TrafficRamp {
        assert!(n >= 1 && rate0 > 0.0 && factor > 0.0);
        let mut phases = Vec::with_capacity(n);
        let mut rate = rate0;
        for _ in 0..n {
            phases.push(RampPhase { rate, duration });
            rate *= factor;
        }
        TrafficRamp::new(phases)
    }

    pub fn total_duration(&self) -> f64 {
        self.phases.iter().map(|p| p.duration).sum()
    }

    /// Index of the phase containing time `t` (clamped to the last phase).
    pub fn phase_at(&self, t: f64) -> usize {
        let mut acc = 0.0;
        for (i, p) in self.phases.iter().enumerate() {
            acc += p.duration;
            if t < acc {
                return i;
            }
        }
        self.phases.len() - 1
    }

    /// Generate the ramp's requests (ids `id0..`), sorted by arrival.
    /// Prompt lengths and sampling parameters come from `profile` (its
    /// own `arrival_rate` is ignored — the ramp owns arrival times).
    pub fn generate(&self, profile: &WorkloadProfile, id0: u64, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed, 0x7a);
        let mut out = Vec::new();
        let mut id = id0;
        let mut phase_start = 0.0;
        for phase in &self.phases {
            let mut t = phase_start;
            loop {
                t += rng.exponential(phase.rate);
                if t >= phase_start + phase.duration {
                    break;
                }
                let len = profile.sample_prompt_len(&mut rng);
                out.push(Request {
                    id,
                    prompt: (0..len as u32).map(|p| p % 251).collect(),
                    params: SamplingParams {
                        temperature: profile.temperature,
                        max_new_tokens: profile.max_new_tokens,
                        eos_token: None,
                    },
                    arrival: t,
                    class: DEFAULT_CLASS,
                });
                id += 1;
            }
            phase_start += phase.duration;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Multi-tenant SLO classes + trace-driven arrivals
// ---------------------------------------------------------------------------

/// One tenant/SLO class of a multi-tenant deployment: who the requests
/// belong to, what latency they are owed, and how the admission scheduler
/// should weigh them ([`crate::scheduler::ClassAwareAdmission`]). The
/// class's index in the launcher's tenant table is its
/// [`crate::batching::ClassId`].
#[derive(Debug, Clone)]
pub struct TenantClass {
    pub name: String,
    /// Admission priority tier (higher = served first; starvation aging
    /// can promote lower tiers — see the scheduler's `aging_tau`).
    pub priority: u32,
    /// Weighted-fairness share *within* a priority tier.
    pub weight: f64,
    /// Fraction of trace arrivals assigned to this class (normalized over
    /// the tenant table by [`ArrivalTrace::to_requests`]).
    pub arrival_weight: f64,
    /// Time-to-first-token SLO, seconds (None = no TTFT promise).
    pub ttft_slo: Option<f64>,
    /// Time-per-output-token SLO, seconds/token.
    pub tpot_slo: Option<f64>,
    /// Expected draft acceptance α for this class's workload — the
    /// admission mix prior used before per-sequence α̂ᵢ measurements
    /// exist (e.g. code tenants ≈ 0.9, open-chat tenants ≈ 0.5).
    pub alpha_hint: Option<f64>,
    /// Per-class cap on concurrently running sequences.
    pub max_running: Option<usize>,
    /// Output budget for requests generated into this class.
    pub max_new_tokens: usize,
    pub temperature: f64,
}

impl TenantClass {
    /// A class with neutral defaults (priority 1, weight 1, no SLOs).
    pub fn new(name: &str) -> TenantClass {
        TenantClass {
            name: name.to_string(),
            priority: 1,
            weight: 1.0,
            arrival_weight: 1.0,
            ttft_slo: None,
            tpot_slo: None,
            alpha_hint: None,
            max_running: None,
            max_new_tokens: 64,
            temperature: 0.0,
        }
    }

    /// The single implicit class of a classless deployment.
    pub fn default_single() -> Vec<TenantClass> {
        vec![TenantClass::new("default")]
    }
}

/// Parse a `--tenants` spec: classes separated by `;`, each
/// `name:key=value,key=value`. Keys: `prio`, `weight`, `share`
/// (arrival weight), `ttft`, `tpot` (seconds), `alpha`, `max_run`,
/// `max_new`, `temp`.
///
/// ```
/// let ts = moesd::workload::parse_tenants(
///     "chat:prio=2,weight=1,share=0.2,ttft=0.5,tpot=0.02,alpha=0.9;\
///      bulk:prio=1,weight=3,share=0.8,alpha=0.5",
/// )
/// .unwrap();
/// assert_eq!(ts.len(), 2);
/// assert_eq!(ts[0].name, "chat");
/// assert_eq!(ts[0].priority, 2);
/// assert_eq!(ts[1].tpot_slo, None);
/// ```
pub fn parse_tenants(spec: &str) -> anyhow::Result<Vec<TenantClass>> {
    let mut out = Vec::new();
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, rest) = match part.split_once(':') {
            Some((n, r)) => (n.trim(), r.trim()),
            None => (part, ""),
        };
        anyhow::ensure!(!name.is_empty(), "tenant class with empty name");
        anyhow::ensure!(
            out.iter().all(|t: &TenantClass| t.name != name),
            "duplicate tenant class `{name}`"
        );
        let mut t = TenantClass::new(name);
        for kv in rest.split(',') {
            let kv = kv.trim();
            if kv.is_empty() {
                continue;
            }
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("tenant `{name}`: expected key=value, got `{kv}`"))?;
            let fval = || -> anyhow::Result<f64> {
                v.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("tenant `{name}`: bad number for {k}: `{v}`"))
            };
            match k.trim() {
                "prio" => t.priority = fval()? as u32,
                "weight" => t.weight = fval()?,
                "share" => t.arrival_weight = fval()?,
                "ttft" => t.ttft_slo = Some(fval()?),
                "tpot" => t.tpot_slo = Some(fval()?),
                "alpha" => t.alpha_hint = Some(fval()?),
                "max_run" => t.max_running = Some(fval()? as usize),
                "max_new" => t.max_new_tokens = fval()? as usize,
                "temp" => t.temperature = fval()?,
                other => anyhow::bail!("tenant `{name}`: unknown key `{other}`"),
            }
        }
        anyhow::ensure!(t.weight > 0.0, "tenant `{name}`: weight must be positive");
        anyhow::ensure!(
            t.arrival_weight >= 0.0,
            "tenant `{name}`: share must be non-negative"
        );
        anyhow::ensure!(t.max_new_tokens >= 1, "tenant `{name}`: max_new must be >= 1");
        if let Some(a) = t.alpha_hint {
            anyhow::ensure!((0.0..=1.0).contains(&a), "tenant `{name}`: alpha out of [0,1]");
        }
        out.push(t);
    }
    anyhow::ensure!(!out.is_empty(), "tenant spec is empty");
    anyhow::ensure!(
        out.iter().any(|t| t.arrival_weight > 0.0),
        "at least one tenant class needs a positive share"
    );
    Ok(out)
}

/// Correlated prompt/output length model. Real production traces show
/// positive prompt↔output correlation (long prompts beget long answers);
/// independent draws understate the tail of total sequence length, which
/// is exactly what KV capacity planning cares about. Draws are a joint
/// lognormal: `z_out = ρ·z_in + √(1−ρ²)·ε`.
#[derive(Debug, Clone, Copy)]
pub struct LengthModel {
    pub prompt_log_mean: f64,
    pub prompt_log_std: f64,
    pub output_log_mean: f64,
    pub output_log_std: f64,
    /// Correlation ρ between the log-lengths, in [-1, 1].
    pub corr: f64,
    pub prompt_clamp: (usize, usize),
    pub output_clamp: (usize, usize),
}

impl LengthModel {
    /// Production-shaped defaults: prompts centered near `prompt_mid`
    /// tokens, outputs near `output_mid`, correlation 0.6.
    pub fn production(prompt_mid: usize, output_mid: usize) -> LengthModel {
        LengthModel {
            prompt_log_mean: (prompt_mid.max(2) as f64).ln(),
            prompt_log_std: 0.6,
            output_log_mean: (output_mid.max(2) as f64).ln(),
            output_log_std: 0.5,
            corr: 0.6,
            prompt_clamp: (4, prompt_mid.max(4) * 8),
            output_clamp: (4, output_mid.max(4) * 8),
        }
    }

    /// One correlated (prompt_len, output_len) draw.
    pub fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        let z_in = rng.normal();
        let eps = rng.normal();
        let rho = self.corr.clamp(-1.0, 1.0);
        let z_out = rho * z_in + (1.0 - rho * rho).sqrt() * eps;
        let p = (self.prompt_log_mean + self.prompt_log_std * z_in).exp();
        let o = (self.output_log_mean + self.output_log_std * z_out).exp();
        (
            (p as usize).clamp(self.prompt_clamp.0, self.prompt_clamp.1),
            (o as usize).clamp(self.output_clamp.0, self.output_clamp.1),
        )
    }
}

/// One arrival in a workload trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Arrival time, seconds from trace start.
    pub t: f64,
    pub prompt_len: usize,
    pub output_len: usize,
}

/// A replayable arrival trace: timestamps plus per-request prompt/output
/// lengths, parsed from CSV (production QPS traces) or generated by the
/// bundled [`ArrivalTrace::synthetic_production`] shape. Traces are
/// rate-rescalable so one trace file sweeps a whole load axis.
#[derive(Debug, Clone)]
pub struct ArrivalTrace {
    events: Vec<TraceEvent>,
}

impl ArrivalTrace {
    pub fn new(mut events: Vec<TraceEvent>) -> anyhow::Result<ArrivalTrace> {
        for e in &events {
            anyhow::ensure!(
                e.t.is_finite() && e.t >= 0.0,
                "trace event with invalid timestamp {e:?}"
            );
            anyhow::ensure!(
                e.prompt_len >= 1 && e.output_len >= 1,
                "trace event with empty prompt/output {e:?}"
            );
        }
        events.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
        Ok(ArrivalTrace { events })
    }

    /// Parse the CSV trace format: `t,prompt_len,output_len` per line, an
    /// optional header line, `#` comments and blank lines skipped.
    pub fn parse_csv(text: &str) -> anyhow::Result<ArrivalTrace> {
        let mut events = Vec::new();
        let mut seen_data = false;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split(',').map(str::trim).collect();
            anyhow::ensure!(
                cols.len() == 3,
                "trace line {}: expected 3 columns, got {}",
                lineno + 1,
                cols.len()
            );
            // The header may sit below comments/blank lines: the first
            // non-skipped row whose first column is non-numeric is it.
            if !seen_data && cols[0].parse::<f64>().is_err() {
                seen_data = true;
                continue;
            }
            seen_data = true;
            let parse = |i: usize| -> anyhow::Result<f64> {
                cols[i]
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("trace line {}: bad number `{}`", lineno + 1, cols[i]))
            };
            events.push(TraceEvent {
                t: parse(0)?,
                prompt_len: parse(1)? as usize,
                output_len: parse(2)? as usize,
            });
        }
        anyhow::ensure!(!events.is_empty(), "trace has no events");
        ArrivalTrace::new(events)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<ArrivalTrace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading trace {}: {e}", path.display()))?;
        ArrivalTrace::parse_csv(&text)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("t,prompt_len,output_len\n");
        for e in &self.events {
            s.push_str(&format!("{:.6},{},{}\n", e.t, e.prompt_len, e.output_len));
        }
        s
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Timestamp of the last arrival (0 for an empty trace).
    pub fn duration(&self) -> f64 {
        self.events.last().map_or(0.0, |e| e.t)
    }

    /// Replay the trace `factor`× faster (timestamps divide by `factor`),
    /// turning one recorded trace into a load axis: factor 2 doubles the
    /// offered QPS with the identical burst structure.
    pub fn rescale_rate(&self, factor: f64) -> ArrivalTrace {
        assert!(factor > 0.0 && factor.is_finite(), "bad rate factor {factor}");
        ArrivalTrace {
            events: self
                .events
                .iter()
                .map(|e| TraceEvent { t: e.t / factor, ..*e })
                .collect(),
        }
    }

    /// The bundled production-shaped synthetic trace: a Markov-modulated
    /// Poisson process (calm/burst states, bursts ≈ 4× the calm rate)
    /// with correlated prompt/output lengths from [`LengthModel`].
    /// Deterministic in `seed`. Prompts are clamped to a serving-realistic
    /// 256 tokens — unbounded lognormal tails make multi-second prefill
    /// waves dominate every latency metric (measured in the python
    /// replica during the multitenant experiment's design).
    pub fn synthetic_production(
        duration_s: f64,
        base_rate: f64,
        seed: u64,
    ) -> ArrivalTrace {
        let lengths = LengthModel {
            prompt_log_mean: (64.0f64).ln(),
            prompt_log_std: 0.6,
            output_log_mean: (48.0f64).ln(),
            output_log_std: 0.5,
            corr: 0.6,
            prompt_clamp: (8, 256),
            output_clamp: (4, 384),
        };
        ArrivalTrace::synthetic_mmpp(duration_s, base_rate, seed, lengths)
    }

    /// Prefill-heavy variant of [`ArrivalTrace::synthetic_production`]:
    /// the identical Markov-modulated arrival process (same burst
    /// structure at the same seed), but prompts centered ≈4× longer
    /// (256 tokens, tail to 1024) with modest outputs. This is the
    /// workload where bulk prefill stalls decode for whole-prompt
    /// forwards — the regime chunked prefill exists for — and it drives
    /// the `bench continuous` TTFT comparison.
    pub fn synthetic_production_heavy(
        duration_s: f64,
        base_rate: f64,
        seed: u64,
    ) -> ArrivalTrace {
        let lengths = LengthModel {
            prompt_log_mean: (256.0f64).ln(),
            prompt_log_std: 0.6,
            output_log_mean: (32.0f64).ln(),
            output_log_std: 0.5,
            corr: 0.6,
            prompt_clamp: (32, 1024),
            output_clamp: (4, 128),
        };
        ArrivalTrace::synthetic_mmpp(duration_s, base_rate, seed, lengths)
    }

    /// Shared Markov-modulated Poisson generator behind the synthetic
    /// trace shapes (calm/burst states, bursts ≈ 4× the calm rate).
    /// Length draws interleave with arrival draws on one RNG stream, so
    /// two shapes with the same seed share burst *timing* only when their
    /// length models are identical.
    fn synthetic_mmpp(
        duration_s: f64,
        base_rate: f64,
        seed: u64,
        lengths: LengthModel,
    ) -> ArrivalTrace {
        assert!(duration_s > 0.0 && base_rate > 0.0);
        let mut rng = Rng::new(seed, 0x7ace);
        let mut events = Vec::new();
        let mut t = 0.0f64;
        let mut bursting = false;
        // State dwell times: calm ~20 s, burst ~5 s (exponential).
        let mut state_end = rng.exponential(1.0 / 20.0);
        while t < duration_s {
            let rate = if bursting { 4.0 * base_rate } else { base_rate };
            t += rng.exponential(rate);
            while t > state_end {
                bursting = !bursting;
                state_end += rng.exponential(if bursting { 1.0 / 5.0 } else { 1.0 / 20.0 });
            }
            if t >= duration_s {
                break;
            }
            let (p, o) = lengths.sample(&mut rng);
            events.push(TraceEvent {
                t,
                prompt_len: p,
                output_len: o,
            });
        }
        ArrivalTrace::new(events).expect("synthetic trace is well-formed")
    }

    /// Materialize the trace as classed engine requests: each event is
    /// assigned a tenant class by the classes' normalized
    /// `arrival_weight`s (deterministic in `seed`), takes its prompt
    /// length from the event, and caps its output budget at the event's
    /// output length (correlated lengths survive into serving).
    pub fn to_requests(
        &self,
        classes: &[TenantClass],
        id0: u64,
        seed: u64,
    ) -> Vec<Request> {
        assert!(!classes.is_empty(), "need at least one tenant class");
        let weights: Vec<f64> = classes.iter().map(|c| c.arrival_weight).collect();
        let mut rng = Rng::new(seed, 0x7e17);
        self.events
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let class: ClassId = if classes.len() == 1 {
                    DEFAULT_CLASS
                } else {
                    rng.categorical(&weights)
                };
                let c = &classes[class];
                Request {
                    id: id0 + i as u64,
                    prompt: (0..e.prompt_len as u32).map(|p| p % 251).collect(),
                    params: SamplingParams {
                        temperature: c.temperature,
                        max_new_tokens: e.output_len.min(c.max_new_tokens.max(1)),
                        eos_token: None,
                    },
                    arrival: e.t,
                    class,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_lengths_in_paper_ranges() {
        let mut rng = Rng::seeded(1);
        for ds in [Dataset::HumanEval, Dataset::MtBench] {
            let p = WorkloadProfile::batch(ds, 0.0, 32);
            let (lo, hi) = ds.prompt_range();
            for _ in 0..500 {
                let l = p.sample_prompt_len(&mut rng);
                assert!(l >= lo && l <= hi, "{}: {l} outside [{lo},{hi}]", ds.name());
            }
        }
    }

    #[test]
    fn sigma_table_monotonicities() {
        // σ decreases with γ (harder to keep a long chain accepted)…
        for &gamma in &[2usize, 3] {
            assert!(
                paper_sigma("qwen2", Dataset::HumanEval, 0.0, gamma)
                    >= paper_sigma("qwen2", Dataset::HumanEval, 0.0, gamma + 1)
            );
        }
        // …and with temperature (more randomness), and from code → chat.
        assert!(
            paper_sigma("qwen2", Dataset::HumanEval, 0.0, 3)
                > paper_sigma("qwen2", Dataset::HumanEval, 1.0, 3)
        );
        assert!(
            paper_sigma("qwen2", Dataset::HumanEval, 0.0, 3)
                > paper_sigma("qwen2", Dataset::MtBench, 0.0, 3)
        );
    }

    #[test]
    fn calibrated_alpha_reproduces_sigma() {
        for &gamma in &[2usize, 3, 4] {
            for ds in [Dataset::HumanEval, Dataset::MtBench] {
                for &temp in &[0.0, 1.0] {
                    let alpha = calibrated_alpha("qwen2", ds, temp, gamma);
                    let sigma_back = theory::sigma_from_alpha(alpha, gamma);
                    let sigma_want = paper_sigma("qwen2", ds, temp, gamma);
                    assert!(
                        (sigma_back - sigma_want).abs() < 1e-6,
                        "γ={gamma} {}: {sigma_back} vs {sigma_want}",
                        ds.name()
                    );
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        let p = WorkloadProfile {
            dataset: Dataset::MtBench,
            temperature: 1.0,
            max_new_tokens: 64,
            arrival_rate: Some(4.0),
        };
        let a = p.generate(50, 0, 9);
        let b = p.generate(50, 0, 9);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        // Batch profile arrives at t=0.
        let batch = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 8).generate(10, 0, 1);
        assert!(batch.iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn ramp_phase_counts_track_rates() {
        let ramp = TrafficRamp::geometric(10.0, 4.0, 3, 20.0); // 10, 40, 160 req/s
        let profile = WorkloadProfile::batch(Dataset::MtBench, 0.0, 16);
        let reqs = ramp.generate(&profile, 0, 5);
        let mut counts = [0usize; 3];
        for r in &reqs {
            counts[ramp.phase_at(r.arrival)] += 1;
        }
        // Expected counts: rate × duration = 200, 800, 3200 (±20%).
        for (i, &want) in [200.0f64, 800.0, 3200.0].iter().enumerate() {
            let got = counts[i] as f64;
            assert!(
                (got - want).abs() / want < 0.2,
                "phase {i}: {got} arrivals vs expected {want}"
            );
        }
        // Sorted and inside the ramp window.
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(reqs.last().unwrap().arrival < ramp.total_duration());
    }

    #[test]
    fn ramp_generation_is_deterministic() {
        let ramp = TrafficRamp::new(vec![
            RampPhase {
                rate: 5.0,
                duration: 10.0,
            },
            RampPhase {
                rate: 50.0,
                duration: 10.0,
            },
        ]);
        let profile = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 8);
        let a = ramp.generate(&profile, 0, 9);
        let b = ramp.generate(&profile, 0, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn ramp_phase_at_boundaries() {
        let ramp = TrafficRamp::geometric(1.0, 2.0, 3, 10.0);
        assert_eq!(ramp.phase_at(0.0), 0);
        assert_eq!(ramp.phase_at(9.99), 0);
        assert_eq!(ramp.phase_at(10.0), 1);
        assert_eq!(ramp.phase_at(25.0), 2);
        assert_eq!(ramp.phase_at(1e9), 2); // clamped past the end
        assert_eq!(ramp.total_duration(), 30.0);
    }

    #[test]
    #[should_panic(expected = "invalid phase")]
    fn ramp_rejects_nonpositive_rate() {
        TrafficRamp::new(vec![RampPhase {
            rate: 0.0,
            duration: 1.0,
        }]);
    }

    #[test]
    fn tenant_spec_parses_and_validates() {
        let ts = parse_tenants(
            "chat:prio=2,weight=1,share=0.2,ttft=0.5,tpot=0.02,alpha=0.9,max_new=32;\
             bulk:prio=1,weight=3,share=0.8,alpha=0.5,max_run=48",
        )
        .unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "chat");
        assert_eq!(ts[0].priority, 2);
        assert_eq!(ts[0].ttft_slo, Some(0.5));
        assert_eq!(ts[0].tpot_slo, Some(0.02));
        assert_eq!(ts[0].alpha_hint, Some(0.9));
        assert_eq!(ts[0].max_new_tokens, 32);
        assert_eq!(ts[1].weight, 3.0);
        assert_eq!(ts[1].max_running, Some(48));
        assert_eq!(ts[1].ttft_slo, None);
        // A bare name is a neutral class.
        let one = parse_tenants("solo").unwrap();
        assert_eq!(one[0].name, "solo");
        assert_eq!(one[0].priority, 1);
        // Rejections.
        for bad in [
            "",
            "a:prio=2;a:prio=1",       // duplicate name
            "a:bogus=1",               // unknown key
            "a:weight=0",              // non-positive weight
            "a:alpha=1.5",             // alpha out of range
            "a:prio",                  // not key=value
            "a:share=0;b:share=0",     // no positive share
        ] {
            assert!(parse_tenants(bad).is_err(), "should reject `{bad}`");
        }
    }

    #[test]
    fn length_model_correlation_is_positive() {
        let m = LengthModel::production(96, 48);
        let mut rng = Rng::seeded(3);
        let draws: Vec<(usize, usize)> = (0..4000).map(|_| m.sample(&mut rng)).collect();
        // Clamps respected.
        for &(p, o) in &draws {
            assert!(p >= m.prompt_clamp.0 && p <= m.prompt_clamp.1);
            assert!(o >= m.output_clamp.0 && o <= m.output_clamp.1);
        }
        // Empirical log-length correlation lands near ρ = 0.6.
        let xs: Vec<f64> = draws.iter().map(|&(p, _)| (p as f64).ln()).collect();
        let ys: Vec<f64> = draws.iter().map(|&(_, o)| (o as f64).ln()).collect();
        let n = xs.len() as f64;
        let (mx, my) = (xs.iter().sum::<f64>() / n, ys.iter().sum::<f64>() / n);
        let cov: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum::<f64>() / n;
        let (vx, vy) = (
            xs.iter().map(|x| (x - mx).powi(2)).sum::<f64>() / n,
            ys.iter().map(|y| (y - my).powi(2)).sum::<f64>() / n,
        );
        let corr = cov / (vx.sqrt() * vy.sqrt());
        assert!(
            (corr - 0.6).abs() < 0.12,
            "sample correlation {corr} should track ρ=0.6"
        );
        // Independent-draw control: ρ = 0 gives near-zero correlation.
        let mut m0 = m;
        m0.corr = 0.0;
        let mut rng = Rng::seeded(4);
        let d0: Vec<(f64, f64)> = (0..4000)
            .map(|_| {
                let (p, o) = m0.sample(&mut rng);
                ((p as f64).ln(), (o as f64).ln())
            })
            .collect();
        let mx = d0.iter().map(|d| d.0).sum::<f64>() / n;
        let my = d0.iter().map(|d| d.1).sum::<f64>() / n;
        let cov = d0.iter().map(|d| (d.0 - mx) * (d.1 - my)).sum::<f64>() / n;
        let vx = d0.iter().map(|d| (d.0 - mx).powi(2)).sum::<f64>() / n;
        let vy = d0.iter().map(|d| (d.1 - my).powi(2)).sum::<f64>() / n;
        assert!((cov / (vx.sqrt() * vy.sqrt())).abs() < 0.1);
    }

    #[test]
    fn trace_csv_roundtrip_and_rescale() {
        let text = "t,prompt_len,output_len\n# comment\n0.5,10,20\n0.1,5,8\n";
        let tr = ArrivalTrace::parse_csv(text).unwrap();
        assert_eq!(tr.len(), 2);
        // A header below comments/blank lines parses too; a non-numeric
        // row after real data stays an error.
        let led = "# generated\n\nt,prompt_len,output_len\n0.1,5,8\n";
        assert_eq!(ArrivalTrace::parse_csv(led).unwrap().len(), 1);
        assert!(ArrivalTrace::parse_csv("0.1,5,8\nt,prompt_len,output_len\n").is_err());
        // Sorted by arrival regardless of file order.
        assert_eq!(tr.events()[0].t, 0.1);
        assert_eq!(tr.events()[1].prompt_len, 10);
        assert!((tr.duration() - 0.5).abs() < 1e-12);
        // Round-trips through the writer.
        let back = ArrivalTrace::parse_csv(&tr.to_csv()).unwrap();
        assert_eq!(back.events(), tr.events());
        // Rate rescale halves timestamps at factor 2.
        let fast = tr.rescale_rate(2.0);
        assert!((fast.duration() - 0.25).abs() < 1e-12);
        assert_eq!(fast.len(), tr.len());
        // Rejections: bad column count, empty trace, zero lengths.
        assert!(ArrivalTrace::parse_csv("1.0,5\n").is_err());
        assert!(ArrivalTrace::parse_csv("# nothing\n").is_err());
        assert!(ArrivalTrace::parse_csv("1.0,0,5\n").is_err());
    }

    #[test]
    fn synthetic_trace_is_deterministic_bursty_and_rate_tracking() {
        let a = ArrivalTrace::synthetic_production(120.0, 8.0, 7);
        let b = ArrivalTrace::synthetic_production(120.0, 8.0, 7);
        assert_eq!(a.events(), b.events());
        // Mean rate sits between calm (8/s) and burst (32/s) and within
        // a generous band of the state-weighted expectation (~12.8/s).
        let rate = a.len() as f64 / 120.0;
        assert!(rate > 8.0 && rate < 32.0, "rate {rate}");
        // Bursts exist: some 1-second window holds >= 3x the calm rate.
        let mut max_window = 0usize;
        for start in 0..120 {
            let lo = start as f64;
            let n = a
                .events()
                .iter()
                .filter(|e| e.t >= lo && e.t < lo + 1.0)
                .count();
            max_window = max_window.max(n);
        }
        assert!(max_window >= 24, "no burst found: peak {max_window}/s");
        // Arrivals stay inside the window and sorted.
        assert!(a.duration() < 120.0);
        for w in a.events().windows(2) {
            assert!(w[0].t <= w[1].t);
        }
    }

    #[test]
    fn heavy_trace_is_prefill_heavy_and_deterministic() {
        let heavy = ArrivalTrace::synthetic_production_heavy(120.0, 4.0, 7);
        let again = ArrivalTrace::synthetic_production_heavy(120.0, 4.0, 7);
        assert_eq!(heavy.events(), again.events());
        let base = ArrivalTrace::synthetic_production(120.0, 4.0, 7);
        let mean_prompt = |t: &ArrivalTrace| {
            t.events().iter().map(|e| e.prompt_len).sum::<usize>() as f64 / t.len() as f64
        };
        // ≈4× longer prompts than the base shape, inside the clamps.
        assert!(
            mean_prompt(&heavy) > 2.5 * mean_prompt(&base),
            "heavy {} vs base {}",
            mean_prompt(&heavy),
            mean_prompt(&base)
        );
        for e in heavy.events() {
            assert!((32..=1024).contains(&e.prompt_len));
            assert!((4..=128).contains(&e.output_len));
        }
        // Prefill work dominates decode work: total prompt tokens exceed
        // total output tokens (the regime chunked prefill targets).
        let (p, o) = heavy.events().iter().fold((0usize, 0usize), |(p, o), e| {
            (p + e.prompt_len, o + e.output_len)
        });
        assert!(p > 3 * o, "prompt tokens {p} vs output tokens {o}");
    }

    #[test]
    fn trace_to_requests_assigns_classes_by_share() {
        let tr = ArrivalTrace::synthetic_production(60.0, 20.0, 9);
        let mut chat = TenantClass::new("chat");
        chat.arrival_weight = 0.25;
        chat.max_new_tokens = 16;
        let mut bulk = TenantClass::new("bulk");
        bulk.arrival_weight = 0.75;
        bulk.max_new_tokens = 1 << 20;
        let reqs = tr.to_requests(&[chat, bulk], 100, 5);
        assert_eq!(reqs.len(), tr.len());
        let n_chat = reqs.iter().filter(|r| r.class == 0).count();
        let frac = n_chat as f64 / reqs.len() as f64;
        assert!((frac - 0.25).abs() < 0.07, "chat share {frac}");
        for (r, e) in reqs.iter().zip(tr.events()) {
            assert_eq!(r.prompt.len(), e.prompt_len);
            assert_eq!(r.arrival, e.t);
            if r.class == 0 {
                assert!(r.params.max_new_tokens <= 16);
            } else {
                // Budget follows the trace's correlated output length.
                assert_eq!(r.params.max_new_tokens, e.output_len);
            }
        }
        assert_eq!(reqs[0].id, 100);
        // Single-class deployments tag everything DEFAULT_CLASS.
        let solo = tr.to_requests(&TenantClass::default_single(), 0, 1);
        assert!(solo.iter().all(|r| r.class == DEFAULT_CLASS));
        // Deterministic in seed.
        let tr2 = ArrivalTrace::synthetic_production(60.0, 20.0, 9);
        let mut chat2 = TenantClass::new("chat");
        chat2.arrival_weight = 0.25;
        chat2.max_new_tokens = 16;
        let mut bulk2 = TenantClass::new("bulk");
        bulk2.arrival_weight = 0.75;
        bulk2.max_new_tokens = 1 << 20;
        let reqs2 = tr2.to_requests(&[chat2, bulk2], 100, 5);
        for (x, y) in reqs.iter().zip(&reqs2) {
            assert_eq!(x.class, y.class);
        }
    }
}
