//! Workload generation: synthetic stand-ins for the paper's HumanEval and
//! MT-Bench evaluations (see DESIGN.md §Substitutions).
//!
//! SD performance depends on the workload only through (a) prompt/output
//! length distributions and (b) the draft acceptance behavior. Both are
//! parameterized directly from the paper:
//!
//! - prompt lengths: tokenized prompts span 38–391 tokens for HumanEval and
//!   5–356 for MT-Bench (§4 "Models and datasets");
//! - acceptance: σ per (dataset, temperature, γ) from Tables 1–2, inverted
//!   through Eq. 5 to the α that drives the synthetic backend. Code at
//!   temperature 0 is most predictable (σ up to 0.95), conversation at
//!   temperature 1 least (σ down to 0.35) — exactly the paper's spread.

use crate::batching::{Request, SamplingParams};
use crate::theory;
use crate::util::rng::Rng;

/// The two evaluation datasets the paper uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    HumanEval,
    MtBench,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::HumanEval => "humaneval",
            Dataset::MtBench => "mtbench",
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Dataset> {
        match name {
            "humaneval" => Ok(Dataset::HumanEval),
            "mtbench" => Ok(Dataset::MtBench),
            other => anyhow::bail!("unknown dataset `{other}`"),
        }
    }

    /// Tokenized-prompt length range reported by the paper.
    pub fn prompt_range(&self) -> (usize, usize) {
        match self {
            Dataset::HumanEval => (38, 391),
            Dataset::MtBench => (5, 356),
        }
    }
}

/// σ values per (model, dataset, temperature, γ) transcribed from the
/// paper's Table 1 (2×GPU-A, the calibration platform). γ is indexed 2..4.
pub fn paper_sigma(model: &str, dataset: Dataset, temp: f64, gamma: usize) -> f64 {
    let hot = temp < 0.5;
    let idx = gamma.clamp(2, 4) - 2;
    // Rows: [γ=2, γ=3, γ=4].
    let table: [f64; 3] = match (model, dataset, hot) {
        ("qwen2", Dataset::HumanEval, true) => [0.94, 0.93, 0.91],
        ("qwen2", Dataset::HumanEval, false) => [0.83, 0.73, 0.67],
        ("qwen2", Dataset::MtBench, true) => [0.71, 0.62, 0.55],
        ("qwen2", Dataset::MtBench, false) => [0.68, 0.57, 0.48],
        ("mixtral", Dataset::HumanEval, true) => [0.78, 0.66, 0.58],
        ("mixtral", Dataset::HumanEval, false) => [0.61, 0.46, 0.39],
        ("mixtral", Dataset::MtBench, true) => [0.61, 0.46, 0.39],
        ("mixtral", Dataset::MtBench, false) => [0.53, 0.43, 0.35],
        // Dense comparison (OPT-30B with OPT-350M): mid-range acceptance.
        ("opt", Dataset::HumanEval, true) => [0.85, 0.80, 0.75],
        ("opt", Dataset::HumanEval, false) => [0.70, 0.62, 0.55],
        ("opt", Dataset::MtBench, true) => [0.68, 0.60, 0.52],
        ("opt", Dataset::MtBench, false) => [0.60, 0.50, 0.44],
        _ => [0.75, 0.65, 0.55],
    };
    table[idx]
}

/// α calibrated so Eq. 5 reproduces the paper's σ at the given γ.
pub fn calibrated_alpha(model: &str, dataset: Dataset, temp: f64, gamma: usize) -> f64 {
    let sigma = paper_sigma(model, dataset, temp, gamma);
    theory::alpha_from_sigma(sigma, gamma.clamp(2, 4))
}

/// A workload profile: how requests look and arrive.
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub dataset: Dataset,
    pub temperature: f64,
    /// Output budget per request (the paper decodes fixed-length windows).
    pub max_new_tokens: usize,
    /// Mean arrival rate (requests/second); `None` = all at t=0 (the
    /// paper's batch experiments).
    pub arrival_rate: Option<f64>,
}

impl WorkloadProfile {
    pub fn batch(dataset: Dataset, temperature: f64, max_new_tokens: usize) -> WorkloadProfile {
        WorkloadProfile {
            dataset,
            temperature,
            max_new_tokens,
            arrival_rate: None,
        }
    }

    /// Draw one prompt length: log-normal shaped into the dataset's range
    /// (long-tailed, as real prompt-length histograms are).
    pub fn sample_prompt_len(&self, rng: &mut Rng) -> usize {
        let (lo, hi) = self.dataset.prompt_range();
        let mid = ((lo + hi) / 2) as f64;
        let raw = rng.lognormal(mid.ln() * 0.92, 0.45);
        (raw as usize).clamp(lo, hi)
    }

    /// Generate `n` requests with ids `id0..id0+n`, sorted by arrival.
    pub fn generate(&self, n: usize, id0: u64, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed, 0x77);
        let mut t = 0.0f64;
        (0..n)
            .map(|i| {
                let arrival = match self.arrival_rate {
                    None => 0.0,
                    Some(rate) => {
                        t += rng.exponential(rate);
                        t
                    }
                };
                let len = self.sample_prompt_len(&mut rng);
                Request {
                    id: id0 + i as u64,
                    prompt: (0..len as u32).map(|p| p % 251).collect(),
                    params: SamplingParams {
                        temperature: self.temperature,
                        max_new_tokens: self.max_new_tokens,
                        eos_token: None,
                    },
                    arrival,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_lengths_in_paper_ranges() {
        let mut rng = Rng::seeded(1);
        for ds in [Dataset::HumanEval, Dataset::MtBench] {
            let p = WorkloadProfile::batch(ds, 0.0, 32);
            let (lo, hi) = ds.prompt_range();
            for _ in 0..500 {
                let l = p.sample_prompt_len(&mut rng);
                assert!(l >= lo && l <= hi, "{}: {l} outside [{lo},{hi}]", ds.name());
            }
        }
    }

    #[test]
    fn sigma_table_monotonicities() {
        // σ decreases with γ (harder to keep a long chain accepted)…
        for &gamma in &[2usize, 3] {
            assert!(
                paper_sigma("qwen2", Dataset::HumanEval, 0.0, gamma)
                    >= paper_sigma("qwen2", Dataset::HumanEval, 0.0, gamma + 1)
            );
        }
        // …and with temperature (more randomness), and from code → chat.
        assert!(
            paper_sigma("qwen2", Dataset::HumanEval, 0.0, 3)
                > paper_sigma("qwen2", Dataset::HumanEval, 1.0, 3)
        );
        assert!(
            paper_sigma("qwen2", Dataset::HumanEval, 0.0, 3)
                > paper_sigma("qwen2", Dataset::MtBench, 0.0, 3)
        );
    }

    #[test]
    fn calibrated_alpha_reproduces_sigma() {
        for &gamma in &[2usize, 3, 4] {
            for ds in [Dataset::HumanEval, Dataset::MtBench] {
                for &temp in &[0.0, 1.0] {
                    let alpha = calibrated_alpha("qwen2", ds, temp, gamma);
                    let sigma_back = theory::sigma_from_alpha(alpha, gamma);
                    let sigma_want = paper_sigma("qwen2", ds, temp, gamma);
                    assert!(
                        (sigma_back - sigma_want).abs() < 1e-6,
                        "γ={gamma} {}: {sigma_back} vs {sigma_want}",
                        ds.name()
                    );
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        let p = WorkloadProfile {
            dataset: Dataset::MtBench,
            temperature: 1.0,
            max_new_tokens: 64,
            arrival_rate: Some(4.0),
        };
        let a = p.generate(50, 0, 9);
        let b = p.generate(50, 0, 9);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        // Batch profile arrives at t=0.
        let batch = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 8).generate(10, 0, 1);
        assert!(batch.iter().all(|r| r.arrival == 0.0));
    }
}
