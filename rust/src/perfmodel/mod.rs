//! The paper's analytic SD-speedup model (Algorithm 1).
//!
//! `ComputeSpeedup(params, B, γ, K, E, σ)` combines the three §3.3 factors:
//!
//! ```text
//! T_T(B, s) = bias + k1·G(B·s; λRP, s̄) + k2·N(B·s) + k3·G(T̄_exp(B·s; ρ); λRP, s̄)
//! T_D(B)    = draft_bias + draft_k·G(B; λRP, s̄)
//! T_rej(B,γ)= reject_bias + reject_k·B·(γ+1)
//! Speedup   = σ·(γ+1) · T_T(B,1) / (γ·T_D(B) + T_T(B,γ+?) + T_rej)
//! ```
//!
//! The 10 relaxation parameters carry the physical meanings and search
//! bounds of Appendix C.2; [`ParamBounds::for_setup`] derives them from the
//! architecture + platform exactly as the appendix prescribes.
//!
//! ## Expert-parallel extension
//!
//! The `*_sharded` variants accept a [`ShardingSpec`] and apply the same
//! structural corollaries the roofline simulator derives for EP groups
//! (§3.4): dense-ramp tokens divide by the EP degree `d` (data-parallel
//! replicas), the expert-loading term `k2·N(t)` divides by `d` (experts
//! partitioned) while the expert-ramp argument `T̄_exp` is d-invariant
//! (global token pool), and the fabric's all-to-all time is added on the
//! physical clock ([`ShardingSpec::comm_time`] — the fitted parameters are
//! seconds, so the units line up). A `d = 1` spec reproduces the
//! unsharded model exactly.
//!
//! ## Per-sequence (ragged) extension
//!
//! The paper states Eq. 4's argmax over γ per workload; acceptance α
//! varies per *sequence*, so the repo extends it: a ragged round gives
//! sequence `i` its own depth γᵢ, commits Σᵢ σ(αᵢ, γᵢ)·(γᵢ+1) expected
//! tokens, and pays one shared round time (packed verify at Σ(γᵢ+1)
//! tokens, draft steps over the shrinking active set). See
//! [`PerfModel::ragged_goodput`] for the objective and
//! [`PerfModel::argmax_gamma_ragged`] for the closed-form water-filling
//! argmax (`γᵢ(θ) = max{γ : αᵢ^γ ≥ θ}` at a common water level θ).

use crate::arch::ModelArch;
use crate::hardware::{Platform, ShardingSpec};
use crate::theory;

/// The 10 fitted relaxation parameters (Appendix C.2 order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfParams {
    /// Fixed (dense-path) parameter loading time, seconds.
    pub bias: f64,
    /// Roofline-ramp intensity of the dense components.
    pub k1: f64,
    /// Loading time per activated expert, seconds.
    pub k2: f64,
    /// Roofline-ramp intensity of the sparse (expert) components.
    pub k3: f64,
    /// Draft model fixed loading time.
    pub draft_bias: f64,
    /// Draft model roofline intensity.
    pub draft_k: f64,
    /// Fixed rejection-sampling overhead.
    pub reject_bias: f64,
    /// Incremental rejection cost per verified token.
    pub reject_k: f64,
    /// Empirical/theoretical ridge-point ratio, λ ∈ [0.2, 1].
    pub lambda: f64,
    /// Roofline growth base, s ∈ [1, 2].
    pub s: f64,
}

pub const N_PARAMS: usize = 10;

impl PerfParams {
    pub fn to_vec(&self) -> [f64; N_PARAMS] {
        [
            self.bias,
            self.k1,
            self.k2,
            self.k3,
            self.draft_bias,
            self.draft_k,
            self.reject_bias,
            self.reject_k,
            self.lambda,
            self.s,
        ]
    }

    pub fn from_slice(v: &[f64]) -> PerfParams {
        assert_eq!(v.len(), N_PARAMS);
        PerfParams {
            bias: v[0],
            k1: v[1],
            k2: v[2],
            k3: v[3],
            draft_bias: v[4],
            draft_k: v[5],
            reject_bias: v[6],
            reject_k: v[7],
            lambda: v[8],
            s: v[9],
        }
    }

    pub fn names() -> [&'static str; N_PARAMS] {
        [
            "bias", "k1", "k2", "k3", "draft_bias", "draft_k", "reject_bias", "reject_k",
            "lambda", "s",
        ]
    }
}

/// Physically-derived search bounds (Appendix C.2).
#[derive(Debug, Clone)]
pub struct ParamBounds {
    pub lo: [f64; N_PARAMS],
    pub hi: [f64; N_PARAMS],
}

impl ParamBounds {
    /// Derive bounds from the target/draft architectures and the platform:
    /// `bias ∈ [V_dense·bytes/BW, 5×]`, `k2 ∈ [V_exp·bytes/BW, 5×]`,
    /// `draft_bias ∈ [V_draft·bytes/BW, 5×]`, intensities `∈ [0, cap]`,
    /// reject terms `∈ [0, T_rej_max]`, `λ ∈ [0.2, 1]`, `s ∈ [1, 2]`.
    pub fn for_setup(
        target: &ModelArch,
        draft: &ModelArch,
        platform: &Platform,
        t_rej_max: f64,
    ) -> ParamBounds {
        let bw = platform.total_mem_bw();
        let bias_min = target.dense_path_bytes() / bw;
        let k2_min = target.bytes_per_expert() * target.layers as f64 / bw;
        let draft_min = draft.total_bytes() / bw;
        // Intensity caps: generous multiples of the fixed-load scales; the
        // appendix leaves these unbounded, but the bounded optimizer wants
        // finite boxes. Fits land far from the caps (asserted in tests).
        let cap = (bias_min * 2000.0).max(1.0);
        ParamBounds {
            lo: [
                bias_min,
                0.0,
                k2_min,
                0.0,
                draft_min,
                0.0,
                0.0,
                0.0,
                0.2,
                1.0 + 1e-9,
            ],
            hi: [
                5.0 * bias_min,
                cap,
                5.0 * k2_min,
                cap,
                5.0 * draft_min,
                cap,
                t_rej_max.max(1e-6),
                t_rej_max.max(1e-6),
                1.0,
                2.0,
            ],
        }
    }

    /// Midpoint of the box — the default optimizer start.
    pub fn midpoint(&self) -> [f64; N_PARAMS] {
        let mut x = [0.0; N_PARAMS];
        for i in 0..N_PARAMS {
            x[i] = 0.5 * (self.lo[i] + self.hi[i]);
        }
        // s near 1 is the physical regime; starting at 1.5 makes G explode.
        x[N_PARAMS - 1] = 1.02;
        x
    }
}

/// One measurement row for fitting (Alg. 1's `M_i`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    pub batch: usize,
    pub gamma: usize,
    /// Activated experts per token (K) of the measured model variant.
    pub k: usize,
    /// Total expert count (E).
    pub e: usize,
    /// Measured σ (accepted fraction of the γ+1 maximum).
    pub sigma: f64,
    /// Measured end-to-end SD speedup (the fitting target).
    pub speedup: f64,
}

/// Total verify-forward tokens of a ragged round: `Σ(γᵢ + 1)` — the
/// packed width the target processes in one forward (and the row count
/// the rejection sampler reads).
pub fn ragged_verify_tokens(gammas: &[usize]) -> usize {
    gammas.iter().map(|&g| g + 1).sum()
}

/// Draft-step schedule of a ragged round: `schedule[g]` is the number of
/// sequences still drafting at sequential draft step `g` (those with
/// `γᵢ > g`), so the draft stage costs `Σ_g T_D(schedule[g])`. Length is
/// `max γᵢ`; a uniform assignment yields `γ` steps at the full batch.
pub fn ragged_draft_schedule(gammas: &[usize]) -> Vec<usize> {
    let gmax = gammas.iter().copied().max().unwrap_or(0);
    (0..gmax)
        .map(|step| gammas.iter().filter(|&&g| g > step).count())
        .collect()
}

/// Candidate γ assignments of the water-filling argmax for a set of
/// acceptance estimates (per sequence, or one per distinct-α̂ group):
/// every uniform depth `0..=γmax` first — so ties collapse to uniform
/// rounds — then one assignment per distinct water level `θ = αᵢᵏ`, with
/// `γᵢ(θ) = max{γ ≤ γmax : αᵢ^γ ≥ θ}` in closed form per entry. This is
/// the single source of the candidate set: the offline argmax
/// ([`PerfModel::argmax_gamma_ragged`]) and the online policy
/// (`control::ModelGuidedPolicy::gamma_for_sequences`) both score
/// exactly these assignments, each with its own cost backend.
pub fn water_fill_assignments(alphas: &[f64], gamma_max: usize) -> Vec<Vec<usize>> {
    let n = alphas.len();
    let mut cands: Vec<Vec<usize>> = (0..=gamma_max).map(|g| vec![g; n]).collect();
    let mut thetas: Vec<f64> = Vec::new();
    for &a in alphas {
        let a = a.clamp(0.0, 1.0);
        for k in 1..=gamma_max {
            let th = a.powi(k as i32);
            if th > 0.0 && !thetas.iter().any(|&x| (x - th).abs() < 1e-12) {
                thetas.push(th);
            }
        }
    }
    for &theta in &thetas {
        cands.push(
            alphas
                .iter()
                .map(|&a| {
                    let a = a.clamp(0.0, 1.0);
                    let mut g = 0;
                    while g < gamma_max && a.powi(g as i32 + 1) >= theta {
                        g += 1;
                    }
                    g
                })
                .collect(),
        );
    }
    cands
}

/// The analytic model, bound to a platform ridge point.
#[derive(Debug, Clone)]
pub struct PerfModel {
    /// Theoretical ridge point of the platform (tokens at the roofline
    /// crossover); λ scales it to the empirical transition.
    pub ridge_point: f64,
}

impl PerfModel {
    pub fn new(platform: &Platform) -> PerfModel {
        PerfModel {
            ridge_point: platform.ridge_point(),
        }
    }

    pub fn with_ridge_point(rp: f64) -> PerfModel {
        PerfModel { ridge_point: rp }
    }

    /// The roofline ramp with its constant removed: Ĝ(t) = G(t) − 1 ≥ 0.
    ///
    /// Deviation from the paper's literal Alg. 1 (which uses k·G(t)): as
    /// s → 1, G(t) → 1 and k1·G degenerates into a second additive
    /// constant that aliases `bias` and lets the optimizer zero out the
    /// expert-activation term while still reaching a low MSE. Subtracting
    /// the constant makes `bias` the unique intercept and forces the
    /// token-dependent structure through Ĝ and N(t); the model family is
    /// otherwise identical (the paper's k·G = k·1 + k·Ĝ).
    fn ramp(&self, p: &PerfParams, t: f64) -> f64 {
        theory::roofline_g(t, p.lambda * self.ridge_point, p.s) - 1.0
    }

    /// Target forward time for `b·s` tokens (Alg. 1 lines 6–8).
    ///
    /// ```
    /// use moesd::perfmodel::{PerfModel, PerfParams};
    /// let model = PerfModel::with_ridge_point(150.0);
    /// let p = PerfParams {
    ///     bias: 0.02, k1: 1e-4, k2: 2e-4, k3: 5e-4,
    ///     draft_bias: 0.001, draft_k: 1e-5,
    ///     reject_bias: 1e-4, reject_k: 1e-7,
    ///     lambda: 0.5, s: 1.02,
    /// };
    /// // More tokens through the gate ⇒ strictly more time (Alg. 1's
    /// // monotone cost surface).
    /// assert!(model.t_target(&p, 64, 1, 8, 64) > model.t_target(&p, 8, 1, 8, 64));
    /// ```
    pub fn t_target(&self, p: &PerfParams, b: usize, s: usize, k: usize, e: usize) -> f64 {
        let t = (b * s) as f64;
        let rho = k as f64 / e as f64;
        let n = theory::expected_active_experts(e, k, (b * s) as u64);
        let load = theory::expert_load(t, rho);
        p.bias + p.k1 * self.ramp(p, t) + p.k2 * n + p.k3 * self.ramp(p, load)
    }

    /// Expert-budgeted target forward time: Alg. 1's cost surface with
    /// the activated-expert count capped at `min(N(t), budget)` (the
    /// MoE-Spec verify budget) and the per-expert load T̄_exp recomputed
    /// against the capped count (`t·K/min(N, budget)` — fewer experts
    /// each absorb more tokens, per Eq. 10's load identity
    /// `T̄_exp = t·K/N`). An uncapped call — `budget = None` *or* any
    /// budget ≥ N(t), hence any budget ≥ E — takes the unbudgeted code
    /// path verbatim, so it is bit-for-bit [`PerfModel::t_target`].
    pub fn t_target_budgeted(
        &self,
        p: &PerfParams,
        b: usize,
        s: usize,
        k: usize,
        e: usize,
        budget: Option<usize>,
    ) -> f64 {
        let n_unc = theory::expected_active_experts(e, k, (b * s) as u64);
        let capped = budget.map_or(false, |bud| (bud as f64) < n_unc);
        if !capped {
            return self.t_target(p, b, s, k, e);
        }
        let t = (b * s) as f64;
        let n = budget.expect("capped implies Some") as f64;
        let load = t * k as f64 / n.max(1e-9);
        p.bias + p.k1 * self.ramp(p, t) + p.k2 * n + p.k3 * self.ramp(p, load)
    }

    /// EP-sharded target forward time: Alg. 1's cost surface re-derived
    /// for `spec.devices()` data-parallel ranks holding `E/d` experts each
    /// (see the module docs for the term-by-term mapping).
    pub fn t_target_sharded(
        &self,
        p: &PerfParams,
        b: usize,
        s: usize,
        k: usize,
        e: usize,
        spec: &ShardingSpec,
    ) -> f64 {
        if !spec.is_sharded() {
            return self.t_target(p, b, s, k, e);
        }
        let d = spec.devices() as f64;
        let t = (b * s) as f64;
        let rho = k as f64 / e as f64;
        let n_rank = theory::ep_active_experts_per_device(e, k, (b * s) as u64, spec.devices());
        let load = theory::expert_load(t, rho);
        p.bias
            + p.k1 * self.ramp(p, t / d)
            + p.k2 * n_rank * spec.imbalance
            + p.k3 * self.ramp(p, load) * spec.imbalance
            + spec.comm_time(t)
    }

    /// Expert-budgeted EP-sharded target forward time: the budget caps
    /// the *global* activation before the per-rank `N/d` split (the
    /// all-to-all still reaches every rank; each just hosts fewer hot
    /// experts). Uncapped calls (`budget = None` or ≥ N(t)) take the
    /// unbudgeted sharded path verbatim.
    pub fn t_target_sharded_budgeted(
        &self,
        p: &PerfParams,
        b: usize,
        s: usize,
        k: usize,
        e: usize,
        spec: &ShardingSpec,
        budget: Option<usize>,
    ) -> f64 {
        if !spec.is_sharded() {
            return self.t_target_budgeted(p, b, s, k, e, budget);
        }
        let n_unc = theory::expected_active_experts(e, k, (b * s) as u64);
        let capped = budget.map_or(false, |bud| (bud as f64) < n_unc);
        if !capped {
            return self.t_target_sharded(p, b, s, k, e, spec);
        }
        let d = spec.devices() as f64;
        let t = (b * s) as f64;
        let n = budget.expect("capped implies Some") as f64;
        let n_rank = n / d;
        let load = t * k as f64 / n.max(1e-9);
        p.bias
            + p.k1 * self.ramp(p, t / d)
            + p.k2 * n_rank * spec.imbalance
            + p.k3 * self.ramp(p, load) * spec.imbalance
            + spec.comm_time(t)
    }

    /// Dense-target variant (factor (1) only; Alg. 1 line 9 shape).
    pub fn t_target_dense(&self, p: &PerfParams, b: usize, s: usize) -> f64 {
        let t = (b * s) as f64;
        p.bias + p.k1 * self.ramp(p, t)
    }

    /// Draft forward time (Alg. 1 line 9).
    pub fn t_draft(&self, p: &PerfParams, b: usize) -> f64 {
        p.draft_bias + p.draft_k * self.ramp(p, b as f64)
    }

    /// Rejection-sampling time.
    pub fn t_reject(&self, p: &PerfParams, b: usize, gamma: usize) -> f64 {
        p.reject_bias + p.reject_k * (b * (gamma + 1)) as f64
    }

    /// Alg. 1 line 3: the full speedup expression.
    ///
    /// ```
    /// use moesd::perfmodel::{Measurement, PerfModel, PerfParams};
    /// let model = PerfModel::with_ridge_point(150.0);
    /// let p = PerfParams {
    ///     bias: 0.02, k1: 1e-4, k2: 2e-4, k3: 5e-4,
    ///     draft_bias: 0.001, draft_k: 1e-5,
    ///     reject_bias: 1e-4, reject_k: 1e-7,
    ///     lambda: 0.5, s: 1.02,
    /// };
    /// let m = Measurement { batch: 16, gamma: 3, k: 8, e: 64, sigma: 0.9, speedup: 0.0 };
    /// let x = model.compute_speedup(&p, &m);
    /// // Bounded by the expected round length σ·(γ+1) (Eq. 4's numerator).
    /// assert!(x > 1.0 && x <= 0.9 * 4.0);
    /// ```
    pub fn compute_speedup(&self, p: &PerfParams, m: &Measurement) -> f64 {
        let t_ar = self.t_target(p, m.batch, 1, m.k, m.e);
        let t_verify = self.t_target(p, m.batch, m.gamma + 1, m.k, m.e);
        let t_draft = self.t_draft(p, m.batch);
        let t_rej = self.t_reject(p, m.batch, m.gamma);
        let round_len = m.sigma * (m.gamma + 1) as f64;
        round_len * t_ar / (m.gamma as f64 * t_draft + t_verify + t_rej)
    }

    /// Eq. 4 speedup over the EP-sharded cost surface: the target terms go
    /// through [`PerfModel::t_target_sharded`]; draft and rejection stages
    /// are topology-independent (the draft replica serves its own rank).
    pub fn compute_speedup_sharded(
        &self,
        p: &PerfParams,
        m: &Measurement,
        spec: &ShardingSpec,
    ) -> f64 {
        let t_ar = self.t_target_sharded(p, m.batch, 1, m.k, m.e, spec);
        let t_verify = self.t_target_sharded(p, m.batch, m.gamma + 1, m.k, m.e, spec);
        let t_draft = self.t_draft(p, m.batch);
        let t_rej = self.t_reject(p, m.batch, m.gamma);
        let round_len = m.sigma * (m.gamma + 1) as f64;
        round_len * t_ar / (m.gamma as f64 * t_draft + t_verify + t_rej)
    }

    /// Sharded target efficiency (§3.1 under a [`ShardingSpec`]).
    pub fn target_efficiency_sharded(
        &self,
        p: &PerfParams,
        m: &Measurement,
        spec: &ShardingSpec,
    ) -> f64 {
        self.t_target_sharded(p, m.batch, 1, m.k, m.e, spec)
            / self.t_target_sharded(p, m.batch, m.gamma + 1, m.k, m.e, spec)
    }

    /// Model-side target efficiency (for Fig. 2/3-style decompositions).
    pub fn target_efficiency(&self, p: &PerfParams, m: &Measurement) -> f64 {
        self.t_target(p, m.batch, 1, m.k, m.e)
            / self.t_target(p, m.batch, m.gamma + 1, m.k, m.e)
    }

    // --- per-sequence Eq. 4 (ragged rounds) --------------------------------

    /// Target forward time over a **packed** token count — the verify pass
    /// of a ragged round, where sequence `i` contributes `γᵢ + 1` tokens
    /// and the forward processes `tokens = Σ(γᵢ+1)` in total. Alg. 1's
    /// cost surface depends on `(B, s)` only through `t = B·s`, so the
    /// packed form is exactly `t_target(tokens, 1)`; a uniform round's
    /// `t_target_tokens(B·(γ+1))` equals `t_target(B, γ+1)` identically.
    pub fn t_target_tokens(&self, p: &PerfParams, tokens: usize, k: usize, e: usize) -> f64 {
        self.t_target(p, tokens, 1, k, e)
    }

    /// Expert-budgeted packed verify price
    /// ([`PerfModel::t_target_budgeted`] in token form).
    pub fn t_target_tokens_budgeted(
        &self,
        p: &PerfParams,
        tokens: usize,
        k: usize,
        e: usize,
        budget: Option<usize>,
    ) -> f64 {
        self.t_target_budgeted(p, tokens, 1, k, e, budget)
    }

    /// Time of one ragged round: the draft runs `max γᵢ` sequential
    /// forwards over the shrinking set of sequences still drafting
    /// ([`ragged_draft_schedule`]), the target verifies the packed
    /// `Σ(γᵢ+1)` tokens in one forward, and rejection sampling reads the
    /// same `Σ(γᵢ+1)` rows. A uniform assignment reproduces the
    /// [`PerfModel::compute_speedup`] denominator.
    pub fn ragged_round_time(&self, p: &PerfParams, gammas: &[usize], k: usize, e: usize) -> f64 {
        let rows = ragged_verify_tokens(gammas);
        let verify = self.t_target_tokens(p, rows, k, e);
        let draft: f64 = ragged_draft_schedule(gammas)
            .iter()
            .map(|&bg| self.t_draft(p, bg))
            .sum();
        let reject = p.reject_bias + p.reject_k * rows as f64;
        draft + verify + reject
    }

    /// Expert-budgeted ragged round time: only the packed verify forward
    /// runs under the budget — drafting and rejection sampling never
    /// touch the target's gate. `budget = None` mirrors
    /// [`PerfModel::ragged_round_time`] term for term (same summation
    /// order), so it is bit-for-bit identical.
    pub fn ragged_round_time_budgeted(
        &self,
        p: &PerfParams,
        gammas: &[usize],
        k: usize,
        e: usize,
        budget: Option<usize>,
    ) -> f64 {
        let rows = ragged_verify_tokens(gammas);
        let verify = self.t_target_tokens_budgeted(p, rows, k, e, budget);
        let draft: f64 = ragged_draft_schedule(gammas)
            .iter()
            .map(|&bg| self.t_draft(p, bg))
            .sum();
        let reject = p.reject_bias + p.reject_k * rows as f64;
        draft + verify + reject
    }

    /// Expected goodput (committed tokens per second, whole batch) of a
    /// mixed-γ round — the per-sequence Eq. 4: each sequence contributes
    /// σ(αᵢ, γᵢ)·(γᵢ+1) expected tokens while the round pays one shared
    /// ragged round time.
    ///
    /// ```
    /// use moesd::perfmodel::{PerfModel, PerfParams};
    /// let model = PerfModel::with_ridge_point(150.0);
    /// let p = PerfParams {
    ///     bias: 0.02, k1: 1e-4, k2: 2e-4, k3: 5e-4,
    ///     draft_bias: 0.001, draft_k: 1e-5,
    ///     reject_bias: 1e-4, reject_k: 1e-7,
    ///     lambda: 0.5, s: 1.02,
    /// };
    /// // A bimodal batch (8 easy α=0.95 sequences, 8 hard α=0.5 ones) at
    /// // per-sequence depths (6, 2) out-produces the uniform compromise
    /// // γ=4 — the argmax the scalar Eq. 4 would pick for the mean α.
    /// let alphas: Vec<f64> = (0..16).map(|i| if i % 2 == 0 { 0.95 } else { 0.5 }).collect();
    /// let ragged: Vec<usize> = (0..16).map(|i| if i % 2 == 0 { 6 } else { 2 }).collect();
    /// let gr = model.ragged_goodput(&p, &ragged, &alphas, 8, 64);
    /// let gu = model.ragged_goodput(&p, &vec![4; 16], &alphas, 8, 64);
    /// assert!(gr > gu, "mixed depths should win: {gr} vs {gu}");
    /// ```
    pub fn ragged_goodput(
        &self,
        p: &PerfParams,
        gammas: &[usize],
        alphas: &[f64],
        k: usize,
        e: usize,
    ) -> f64 {
        assert_eq!(gammas.len(), alphas.len(), "gammas/alphas length mismatch");
        assert!(!gammas.is_empty(), "ragged goodput needs at least one sequence");
        theory::ragged_round_tokens(alphas, gammas) / self.ragged_round_time(p, gammas, k, e)
    }

    /// Expert-budgeted ragged goodput — the (γ⃗, budget) objective the
    /// joint water-fill maximizes. Two budget effects compose:
    /// the packed verify gets cheaper
    /// ([`PerfModel::ragged_round_time_budgeted`]) while every
    /// sequence's acceptance degrades by the coverage curve
    /// (`α_eff = α·coverage^sensitivity`,
    /// [`theory::budgeted_alpha`], with coverage evaluated at this
    /// round's verify width `Σ(γᵢ+1)`). Full coverage — `budget = None`
    /// or ≥ N(t) — short-circuits to the raw α vector, making the
    /// off-switch bit-exact against [`PerfModel::ragged_goodput`].
    #[allow(clippy::too_many_arguments)]
    pub fn ragged_goodput_budgeted(
        &self,
        p: &PerfParams,
        gammas: &[usize],
        alphas: &[f64],
        k: usize,
        e: usize,
        budget: Option<usize>,
        sensitivity: f64,
    ) -> f64 {
        assert_eq!(gammas.len(), alphas.len(), "gammas/alphas length mismatch");
        assert!(!gammas.is_empty(), "ragged goodput needs at least one sequence");
        let rows = ragged_verify_tokens(gammas);
        let cov = theory::budget_coverage(e, k, rows as u64, budget);
        let tokens = if cov >= 1.0 {
            theory::ragged_round_tokens(alphas, gammas)
        } else {
            let eff: Vec<f64> = alphas
                .iter()
                .map(|&a| theory::budgeted_alpha(a.clamp(0.0, 1.0), cov, sensitivity))
                .collect();
            theory::ragged_round_tokens(&eff, gammas)
        };
        tokens / self.ragged_round_time_budgeted(p, gammas, k, e, budget)
    }

    /// Closed-form argmax of the per-sequence Eq. 4: the water-filling
    /// rule. The marginal expected tokens from extending sequence `i`'s
    /// draft from `γ` to `γ+1` is `αᵢ^{γ+1}` (the probability the whole
    /// extended prefix is accepted), while the marginal round-time cost of
    /// one more verify token is shared across the batch — so at the
    /// optimum every sequence drafts while its marginal stays above one
    /// common water level θ, giving `γᵢ(θ) = max{γ ≤ γmax : αᵢ^γ ≥ θ}` in
    /// closed form per sequence. The water level itself is found by
    /// sweeping the (at most `distinct-α × γmax`) candidate marginals plus
    /// every uniform assignment and keeping the assignment with the
    /// highest [`PerfModel::ragged_goodput`]; with uniform α the
    /// candidates collapse to uniform assignments and the result is the
    /// scalar Eq. 4 argmax.
    ///
    /// ```
    /// use moesd::perfmodel::{PerfModel, PerfParams};
    /// let model = PerfModel::with_ridge_point(150.0);
    /// let p = PerfParams {
    ///     bias: 0.02, k1: 1e-4, k2: 2e-4, k3: 5e-4,
    ///     draft_bias: 0.001, draft_k: 1e-5,
    ///     reject_bias: 1e-4, reject_k: 1e-7,
    ///     lambda: 0.5, s: 1.02,
    /// };
    /// let alphas: Vec<f64> = (0..16).map(|i| if i % 2 == 0 { 0.95 } else { 0.5 }).collect();
    /// let gammas = model.argmax_gamma_ragged(&p, &alphas, 8, 8, 64);
    /// // Easy sequences get strictly deeper drafts than hard ones.
    /// assert!(gammas[0] > gammas[1]);
    /// // And the assignment is at least as good as every uniform γ.
    /// let best = model.ragged_goodput(&p, &gammas, &alphas, 8, 64);
    /// for g in 0..=8usize {
    ///     let uni = model.ragged_goodput(&p, &vec![g; 16], &alphas, 8, 64);
    ///     assert!(best >= uni, "uniform γ={g} beat the water-fill");
    /// }
    /// ```
    pub fn argmax_gamma_ragged(
        &self,
        p: &PerfParams,
        alphas: &[f64],
        gamma_max: usize,
        k: usize,
        e: usize,
    ) -> Vec<usize> {
        assert!(!alphas.is_empty(), "argmax needs at least one sequence");
        let mut best: Vec<usize> = Vec::new();
        let mut best_score = f64::MIN;
        for cand in water_fill_assignments(alphas, gamma_max) {
            let s = self.ragged_goodput(p, &cand, alphas, k, e);
            if s > best_score {
                best_score = s;
                best = cand;
            }
        }
        best
    }

    /// Joint (γ⃗, budget) argmax over the budgeted per-sequence Eq. 4:
    /// the PR-4 water-fill candidate set (one source:
    /// [`water_fill_assignments`], generated from the *raw* α vector —
    /// the budget rescales every sequence's α by the same coverage
    /// factor, which preserves the water-level order) crossed with
    /// `{None} ∪ budgets`, scored by
    /// [`PerfModel::ragged_goodput_budgeted`]. `None` is scored first
    /// and improvements are strict, so with an **empty** budget grid the
    /// scan degenerates to [`PerfModel::argmax_gamma_ragged`] exactly —
    /// same candidates, same scores, same tie-breaks (pinned in
    /// `rust/tests/integration_budget.rs`). Because the budget-blind
    /// water-fill assignment is itself in the candidate set, the joint
    /// optimum can never lose to picking γ⃗ first and sweeping budgets
    /// after (decoupled selection).
    #[allow(clippy::too_many_arguments)]
    pub fn argmax_gamma_budget_ragged(
        &self,
        p: &PerfParams,
        alphas: &[f64],
        gamma_max: usize,
        k: usize,
        e: usize,
        budgets: &[usize],
        sensitivity: f64,
    ) -> (Vec<usize>, Option<usize>) {
        assert!(!alphas.is_empty(), "argmax needs at least one sequence");
        let mut grid: Vec<Option<usize>> = vec![None];
        grid.extend(budgets.iter().map(|&b| Some(b)));
        let mut best: Vec<usize> = Vec::new();
        let mut best_budget: Option<usize> = None;
        let mut best_score = f64::MIN;
        for &bud in &grid {
            for cand in water_fill_assignments(alphas, gamma_max) {
                let s = self.ragged_goodput_budgeted(p, &cand, alphas, k, e, bud, sensitivity);
                if s > best_score {
                    best_score = s;
                    best = cand;
                    best_budget = bud;
                }
            }
        }
        (best, best_budget)
    }

    /// Residual vector for the Alg. 1 line-13 least-squares objective.
    pub fn residuals(&self, p: &PerfParams, ms: &[Measurement]) -> Vec<f64> {
        ms.iter()
            .map(|m| self.compute_speedup(p, m) - m.speedup)
            .collect()
    }

    /// Mean squared error over a measurement set (the Table 3 column).
    pub fn mse(&self, p: &PerfParams, ms: &[Measurement]) -> f64 {
        let r = self.residuals(p, ms);
        r.iter().map(|x| x * x).sum::<f64>() / r.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::hardware::platform_2x_gpu_a;

    fn demo_params() -> PerfParams {
        PerfParams {
            bias: 0.02,
            k1: 1e-4,
            k2: 2e-4,
            k3: 5e-4,
            draft_bias: 0.001,
            draft_k: 1e-5,
            reject_bias: 1e-4,
            reject_k: 1e-7,
            lambda: 0.5,
            s: 1.02,
        }
    }

    fn model() -> PerfModel {
        PerfModel::new(&platform_2x_gpu_a())
    }

    #[test]
    fn roundtrip_params_vec() {
        let p = demo_params();
        let p2 = PerfParams::from_slice(&p.to_vec());
        assert_eq!(p, p2);
    }

    #[test]
    fn t_target_monotone_in_tokens() {
        let m = model();
        let p = demo_params();
        let mut prev = 0.0;
        for b in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            let t = m.t_target(&p, b, 1, 8, 64);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn verify_overhead_shrinks_at_moderate_batch() {
        let m = model();
        let p = demo_params();
        let overhead = |b: usize| {
            m.t_target(&p, b, 4, 8, 64) / m.t_target(&p, b, 1, 8, 64)
        };
        // The relative cost of processing 4× tokens should dip between B=1
        // (expert loading penalty) and saturation (compute-bound).
        let small = overhead(1);
        let moderate = overhead(24);
        assert!(
            moderate < small,
            "verify overhead should shrink: B=1 {small} vs B=24 {moderate}"
        );
    }

    #[test]
    fn speedup_shape_first_up_then_down() {
        let m = model();
        let p = demo_params();
        let batches = [1usize, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512];
        let speedups: Vec<f64> = batches
            .iter()
            .map(|&b| {
                m.compute_speedup(
                    &p,
                    &Measurement {
                        batch: b,
                        gamma: 3,
                        k: 8,
                        e: 64,
                        sigma: 0.9,
                        speedup: 0.0,
                    },
                )
            })
            .collect();
        let peak = crate::util::stats::argmax(&speedups);
        assert!(peak > 0 && peak < batches.len() - 1, "{speedups:?}");
        assert!(speedups[peak] > speedups[0]);
        assert!(speedups[peak] > *speedups.last().unwrap());
    }

    #[test]
    fn sigma_scales_speedup_linearly() {
        let m = model();
        let p = demo_params();
        let mk = |sigma: f64| Measurement {
            batch: 16,
            gamma: 3,
            k: 8,
            e: 64,
            sigma,
            speedup: 0.0,
        };
        let s1 = m.compute_speedup(&p, &mk(0.5));
        let s2 = m.compute_speedup(&p, &mk(1.0));
        assert!((s2 / s1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bounds_are_physical() {
        let target = presets::qwen2_57b_a14b();
        let draft = presets::qwen2_0_5b();
        let b = ParamBounds::for_setup(&target, &draft, &platform_2x_gpu_a(), 1e-3);
        // bias_min: dense-path bytes over aggregate bandwidth — order ms.
        assert!(b.lo[0] > 1e-4 && b.lo[0] < 0.2, "bias_min={}", b.lo[0]);
        assert!((b.hi[0] / b.lo[0] - 5.0).abs() < 1e-9);
        // k2: one expert across all layers — much smaller than bias.
        assert!(b.lo[2] < b.lo[0]);
        // λ and s boxes.
        assert_eq!(b.lo[8], 0.2);
        assert_eq!(b.hi[8], 1.0);
        assert!(b.hi[9] <= 2.0);
        // Midpoint inside the box.
        let mid = b.midpoint();
        for i in 0..N_PARAMS {
            assert!(mid[i] >= b.lo[i] && mid[i] <= b.hi[i], "param {i}");
        }
    }

    #[test]
    fn residuals_and_mse() {
        let m = model();
        let p = demo_params();
        let meas = Measurement {
            batch: 16,
            gamma: 3,
            k: 8,
            e: 64,
            sigma: 0.9,
            speedup: 1.5,
        };
        let pred = m.compute_speedup(&p, &meas);
        let r = m.residuals(&p, &[meas]);
        assert!((r[0] - (pred - 1.5)).abs() < 1e-12);
        assert!((m.mse(&p, &[meas]) - r[0] * r[0]).abs() < 1e-12);
    }

    #[test]
    fn sharded_single_rank_is_identity() {
        use crate::hardware::ShardingSpec;
        let m = model();
        let p = demo_params();
        let spec = ShardingSpec::single();
        for (b, s) in [(1usize, 1usize), (16, 4), (256, 5)] {
            assert_eq!(
                m.t_target_sharded(&p, b, s, 8, 64, &spec),
                m.t_target(&p, b, s, 8, 64)
            );
        }
        let meas = Measurement {
            batch: 16,
            gamma: 3,
            k: 8,
            e: 64,
            sigma: 0.9,
            speedup: 0.0,
        };
        assert_eq!(
            m.compute_speedup_sharded(&p, &meas, &spec),
            m.compute_speedup(&p, &meas)
        );
    }

    #[test]
    fn sharding_lifts_model_target_efficiency_and_fabric_drags_it() {
        use crate::hardware::{ShardingSpec, Topology};
        let m = model();
        let p = demo_params();
        let arch = presets::qwen2_57b_a14b();
        let meas = Measurement {
            batch: 16,
            gamma: 3,
            k: 8,
            e: 64,
            sigma: 0.9,
            speedup: 0.0,
        };
        let nv = ShardingSpec::for_arch(Topology::nvlink(4), &arch);
        let pc = ShardingSpec::for_arch(Topology::pcie(4), &arch);
        let base = m.target_efficiency(&p, &meas);
        let e_nv = m.target_efficiency_sharded(&p, &meas, &nv);
        let e_pc = m.target_efficiency_sharded(&p, &meas, &pc);
        // Same corollary the roofline simulator shows: splitting the k2
        // expert-loading term across ranks shrinks the verify-step growth.
        assert!(e_nv > base, "EP should lift model teff: {e_nv} vs {base}");
        // A slow fabric adds token-linear cost, dragging teff back down.
        assert!(e_pc < e_nv, "PCIe fabric should cost teff: {e_pc} vs {e_nv}");
        // Speedup stays finite, positive, and Eq. 4-bounded everywhere.
        for spec in [&nv, &pc] {
            for b in [1usize, 16, 256, 2048] {
                let mm = Measurement { batch: b, ..meas };
                let x = m.compute_speedup_sharded(&p, &mm, spec);
                assert!(x.is_finite() && x > 0.0 && x <= 0.9 * 4.0 + 1e-9, "x={x} B={b}");
            }
        }
    }

    #[test]
    fn sharded_imbalance_raises_cost() {
        use crate::hardware::{ShardingSpec, Topology};
        let m = model();
        let p = demo_params();
        let arch = presets::qwen2_57b_a14b();
        let spec = ShardingSpec::for_arch(Topology::nvlink(4), &arch);
        let skew = spec.clone().with_imbalance(1.5);
        assert!(
            m.t_target_sharded(&p, 32, 4, 8, 64, &skew)
                > m.t_target_sharded(&p, 32, 4, 8, 64, &spec)
        );
    }

    #[test]
    fn ragged_helpers_shapes() {
        assert_eq!(ragged_verify_tokens(&[3, 0, 5]), 11);
        assert_eq!(ragged_verify_tokens(&[4, 4]), 10);
        assert_eq!(ragged_draft_schedule(&[3, 0, 5]), vec![2, 2, 2, 1, 1]);
        assert_eq!(ragged_draft_schedule(&[2, 2]), vec![2, 2]);
        assert!(ragged_draft_schedule(&[0, 0]).is_empty());
    }

    #[test]
    fn water_fill_candidates_cover_uniforms_and_levels() {
        let cands = water_fill_assignments(&[0.9, 0.5], 4);
        // Uniforms first: 0..=4.
        for (g, c) in cands.iter().take(5).enumerate() {
            assert_eq!(c, &vec![g; 2]);
        }
        // Every θ candidate is monotone in α (deeper drafts for higher α)
        // and within bounds.
        for c in &cands[5..] {
            assert!(c[0] >= c[1], "water level must favor the higher α: {c:?}");
            assert!(c.iter().all(|&g| g <= 4));
        }
        // Distinct θ levels: 0.9^k and 0.5^k for k=1..4, all distinct → 8.
        assert_eq!(cands.len(), 5 + 8);
        // α=1 drafts at γmax for every level; α=0 never drafts.
        let degenerate = water_fill_assignments(&[1.0, 0.0], 3);
        for c in &degenerate[4..] {
            assert_eq!(c[0], 3);
            assert_eq!(c[1], 0);
        }
    }

    #[test]
    fn ragged_uniform_round_matches_scalar_denominator() {
        // A uniform assignment must reproduce the compute_speedup
        // denominator (up to float-summation order in the draft term).
        let m = model();
        let p = demo_params();
        let (b, gamma) = (16usize, 3usize);
        let scalar = gamma as f64 * m.t_draft(&p, b)
            + m.t_target(&p, b, gamma + 1, 8, 64)
            + m.t_reject(&p, b, gamma);
        let ragged = m.ragged_round_time(&p, &vec![gamma; b], 8, 64);
        assert!((ragged - scalar).abs() < 1e-12 * scalar.max(1.0), "{ragged} vs {scalar}");
        // Packed verify is exactly the uniform-width verify.
        assert_eq!(
            m.t_target_tokens(&p, b * (gamma + 1), 8, 64),
            m.t_target(&p, b, gamma + 1, 8, 64)
        );
    }

    #[test]
    fn water_fill_beats_every_uniform_on_bimodal_alpha() {
        // Validated against the python replica of this model: bimodal
        // α = 0.95/0.5 at B=16 — the water-fill lands on the (8, 5)
        // pattern with goodput ≈ 1790 tok/s vs 1784 for the best uniform
        // (γ=8) and 1382 for the mean-α compromise γ=4.
        let m = model();
        let p = demo_params();
        let alphas: Vec<f64> = (0..16)
            .map(|i| if i % 2 == 0 { 0.95 } else { 0.5 })
            .collect();
        let assignment = m.argmax_gamma_ragged(&p, &alphas, 8, 8, 64);
        assert!(assignment[0] > assignment[1], "{assignment:?}");
        let best = m.ragged_goodput(&p, &assignment, &alphas, 8, 64);
        for g in 0..=8usize {
            let uni = m.ragged_goodput(&p, &vec![g; 16], &alphas, 8, 64);
            assert!(best >= uni, "uniform γ={g} ({uni}) beat water-fill ({best})");
        }
    }

    #[test]
    fn water_fill_uniform_alpha_is_the_scalar_argmax() {
        // Uniform-α inputs reproduce the scalar Eq. 4 argmax exactly (the
        // "uniform special case" the issue requires).
        let m = model();
        let p = demo_params();
        for &(alpha, batch) in &[(0.85f64, 16usize), (0.6, 64), (0.95, 4)] {
            let alphas = vec![alpha; batch];
            let assignment = m.argmax_gamma_ragged(&p, &alphas, 8, 8, 64);
            assert!(
                assignment.windows(2).all(|w| w[0] == w[1]),
                "uniform α must yield a uniform assignment: {assignment:?}"
            );
            let scalar_best = (0..=8usize)
                .max_by(|&a, &b| {
                    let ga = m.ragged_goodput(&p, &vec![a; batch], &alphas, 8, 64);
                    let gb = m.ragged_goodput(&p, &vec![b; batch], &alphas, 8, 64);
                    ga.partial_cmp(&gb).unwrap()
                })
                .unwrap();
            assert_eq!(assignment[0], scalar_best, "α={alpha} B={batch}");
        }
    }

    #[test]
    fn budget_off_switch_is_bit_identical() {
        use crate::hardware::{ShardingSpec, Topology};
        let m = model();
        let p = demo_params();
        let arch = presets::qwen2_57b_a14b();
        let spec = ShardingSpec::for_arch(Topology::nvlink(4), &arch);
        for (b, s) in [(1usize, 1usize), (16, 4), (256, 5)] {
            let want = m.t_target(&p, b, s, 8, 64);
            assert_eq!(m.t_target_budgeted(&p, b, s, 8, 64, None), want);
            assert_eq!(m.t_target_budgeted(&p, b, s, 8, 64, Some(64)), want);
            assert_eq!(m.t_target_budgeted(&p, b, s, 8, 64, Some(999)), want);
            let want_sh = m.t_target_sharded(&p, b, s, 8, 64, &spec);
            assert_eq!(
                m.t_target_sharded_budgeted(&p, b, s, 8, 64, &spec, None),
                want_sh
            );
            assert_eq!(
                m.t_target_sharded_budgeted(&p, b, s, 8, 64, &spec, Some(64)),
                want_sh
            );
        }
        let gammas = [5usize, 2, 3, 0, 5, 1];
        let alphas = [0.9, 0.5, 0.7, 0.3, 0.95, 0.6];
        assert_eq!(
            m.ragged_round_time_budgeted(&p, &gammas, 8, 64, None),
            m.ragged_round_time(&p, &gammas, 8, 64)
        );
        assert_eq!(
            m.ragged_goodput_budgeted(&p, &gammas, &alphas, 8, 64, None, 0.5),
            m.ragged_goodput(&p, &gammas, &alphas, 8, 64)
        );
        assert_eq!(
            m.ragged_goodput_budgeted(&p, &gammas, &alphas, 8, 64, Some(64), 0.5),
            m.ragged_goodput(&p, &gammas, &alphas, 8, 64)
        );
    }

    #[test]
    fn tight_budget_cuts_verify_price_and_alpha() {
        let m = model();
        let p = demo_params();
        // t = 28 tokens activates N ≈ 62.5 of 64 experts; a budget of 24
        // must strictly cut the k2 term's price.
        let full = m.t_target_tokens(&p, 28, 8, 64);
        let b24 = m.t_target_tokens_budgeted(&p, 28, 8, 64, Some(24));
        let b12 = m.t_target_tokens_budgeted(&p, 28, 8, 64, Some(12));
        assert!(b24 < full, "budget must cheapen the verify: {b24} vs {full}");
        assert!(b12 < b24, "tighter budget is cheaper: {b12} vs {b24}");
        // The acceptance side pays: goodput under a tight budget with a
        // harsh sensitivity can lose to unbudgeted.
        let gammas = vec![6usize; 4];
        let alphas = vec![0.9f64; 4];
        let g_none = m.ragged_goodput_budgeted(&p, &gammas, &alphas, 8, 64, None, 1.0);
        let g_tight = m.ragged_goodput_budgeted(&p, &gammas, &alphas, 8, 64, Some(4), 4.0);
        assert!(
            g_tight < g_none,
            "harsh degradation should not pay: {g_tight} vs {g_none}"
        );
    }

    #[test]
    fn joint_argmax_empty_grid_degenerates_exactly() {
        let m = model();
        let p = demo_params();
        let cases: Vec<Vec<f64>> = vec![
            (0..16).map(|i| if i % 2 == 0 { 0.95 } else { 0.5 }).collect(),
            vec![0.85; 8],
            vec![0.3, 0.6, 0.9, 0.99],
            vec![0.7],
        ];
        for alphas in &cases {
            let plain = m.argmax_gamma_ragged(&p, alphas, 8, 8, 64);
            let (joint, bud) = m.argmax_gamma_budget_ragged(&p, alphas, 8, 8, 64, &[], 0.5);
            assert_eq!(joint, plain, "empty grid must reproduce PR-4 water-fill");
            assert_eq!(bud, None);
        }
    }

    #[test]
    fn joint_argmax_never_loses_to_decoupled_selection() {
        let m = model();
        let p = demo_params();
        let sens = 0.35;
        let budgets = [8usize, 16, 24, 32, 48];
        for alphas in [
            (0..8).map(|i| if i % 2 == 0 { 0.95 } else { 0.55 }).collect::<Vec<f64>>(),
            vec![0.9; 4],
            vec![0.4, 0.8, 0.95, 0.99, 0.6, 0.7],
        ] {
            let (joint, jbud) = m.argmax_gamma_budget_ragged(&p, &alphas, 8, 8, 64, &budgets, sens);
            let joint_score =
                m.ragged_goodput_budgeted(&p, &joint, &alphas, 8, 64, jbud, sens);
            // Decoupled: pick γ⃗ budget-blind, then sweep budgets over it.
            let blind = m.argmax_gamma_ragged(&p, &alphas, 8, 8, 64);
            let mut decoupled = m.ragged_goodput_budgeted(&p, &blind, &alphas, 8, 64, None, sens);
            for &b in &budgets {
                let s = m.ragged_goodput_budgeted(&p, &blind, &alphas, 8, 64, Some(b), sens);
                decoupled = decoupled.max(s);
            }
            assert!(
                joint_score >= decoupled - 1e-12,
                "joint ({joint_score}) must not lose to decoupled ({decoupled})"
            );
        }
    }

    #[test]
    fn dense_variant_has_no_expert_terms() {
        let m = model();
        let mut p = demo_params();
        p.k2 = 1.0; // would dominate if (wrongly) applied
        p.k3 = 1.0;
        let td = m.t_target_dense(&p, 8, 1);
        assert!(td < p.bias + p.k1 * 1e4);
    }
}
