//! Cross-module property tests on the coordinator's invariants (the
//! "proptest on coordinator invariants: routing, batching, state" suite).

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::engine::{Engine, EngineConfig};
use moesd::hardware::{platform_2x_gpu_a, ShardingSpec, Topology};
use moesd::kvcache::KvConfig;
use moesd::sampling::{verify_chain, verify_chain_views, LogitsView};
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::routing::Router;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::spec::SdBackend;
use moesd::testkit::{ensure, Runner};
use moesd::theory;
use moesd::util::rng::Rng;

fn mk_engine(alpha: f64, gamma: usize, max_batch: usize, blocks: usize, seed: u64)
    -> Engine<SyntheticLm> {
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    Engine::new(
        EngineConfig {
            gamma,
            kv: KvConfig {
                num_blocks: blocks,
                block_size: 8,
            },
            scheduler: SchedulerConfig {
                max_batch,
                admit_reserve_tokens: 8,
                tpot_slo: None,
            },
            seed,
            ..Default::default()
        },
        SyntheticLm::new(target, draft, alpha, seed),
    )
}

/// Every engine run — any α, γ, batch limit, cache size — terminates with
/// all requests complete, the exact deterministic chain emitted, and KV
/// block conservation intact.
#[test]
fn prop_engine_always_completes_correctly() {
    let mut runner = Runner::new("engine_completes");
    runner.run(25, |g| {
        let alpha = g.f64_in(0.0, 1.0);
        let gamma = g.usize_in(0, 5);
        let max_batch = g.usize_in(1, 12);
        let blocks = g.usize_in(40, 400);
        let n_reqs = g.usize_in(1, 10);
        let seed = g.u64_in(0, 1 << 20);
        let mut engine = mk_engine(alpha, gamma, max_batch, blocks, seed);
        let mut specs = Vec::new();
        for id in 0..n_reqs as u64 {
            let prompt_len = g.usize_in(2, 24);
            let max_new = g.usize_in(1, 24);
            specs.push((id, prompt_len, max_new));
            engine.submit(Request {
                id,
                prompt: (0..prompt_len as u32).collect(),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: max_new,
                    eos_token: None,
                },
                arrival: 0.0,
                class: 0,
            });
        }
        let done = match engine.run_to_completion(200_000) {
            Ok(d) => d,
            Err(e) => return Err(format!("did not complete: {e}")),
        };
        if done.len() != n_reqs {
            return Err(format!("{} of {n_reqs} completed", done.len()));
        }
        for c in &done {
            let (_, prompt_len, max_new) =
                specs.iter().find(|(id, _, _)| *id == c.id).unwrap();
            if c.tokens.len() != *max_new {
                return Err(format!("seq {}: {} tokens != {max_new}", c.id, c.tokens.len()));
            }
            let expect = engine.backend().expected_chain(c.id, *prompt_len, *max_new);
            if c.tokens != expect {
                return Err(format!("seq {}: wrong tokens (losslessness broken)", c.id));
            }
        }
        if let Err(e) = engine.kv().check_invariants() {
            return Err(format!("KV invariant: {e}"));
        }
        ensure(true, "")
    });
}

/// Rejection sampling never emits more than accepted+1 tokens, and with
/// identical target/draft distributions accepts everything.
#[test]
fn prop_verify_chain_length_and_identity() {
    let mut runner = Runner::new("verify_chain");
    runner.run(300, |g| {
        let vocab = g.usize_in(2, 32);
        let gamma = g.usize_in(0, 6);
        let mut rng = Rng::seeded(g.u64_in(0, 1 << 30));
        let mk = |rng: &mut Rng| -> Vec<f64> {
            let v: Vec<f64> = (0..vocab).map(|_| rng.f64() + 0.01).collect();
            let s: f64 = v.iter().sum();
            v.into_iter().map(|x| x / s).collect()
        };
        let draft_probs: Vec<Vec<f64>> = (0..gamma).map(|_| mk(&mut rng)).collect();
        let target_probs: Vec<Vec<f64>> = (0..=gamma).map(|_| mk(&mut rng)).collect();
        let draft_tokens: Vec<u32> = draft_probs
            .iter()
            .map(|d| rng.categorical(d) as u32)
            .collect();
        let out = verify_chain(&draft_tokens, &draft_probs, &target_probs, &mut rng);
        if out.tokens.len() != out.accepted + 1 || out.accepted > gamma {
            return Err(format!(
                "bad outcome: {} tokens, {} accepted, γ={gamma}",
                out.tokens.len(),
                out.accepted
            ));
        }
        // Identity case: draft == target ⇒ full acceptance.
        let same = verify_chain(&draft_tokens, &draft_probs,
            &{
                let mut t = draft_probs.clone();
                t.push(mk(&mut rng));
                t
            }, &mut rng);
        if same.accepted != gamma {
            return Err("identical distributions must fully accept".into());
        }
        ensure(true, "")
    });
}

/// The tentpole equivalence, synthetic-oracle regime: `verify_chain` over
/// sparse one-hot `LogitsView`s emits byte-identical token streams to the
/// dense reference path, across α ∈ {0, 0.5, 1}, γ ∈ 0..=4, and vocab ∈
/// {64, 4096, 151936}, with the two RNG streams staying in lockstep.
#[test]
fn prop_sparse_dense_equivalence_one_hot_chains() {
    for &vocab in &[64usize, 4096, 151_936] {
        for &alpha in &[0.0f64, 0.5, 1.0] {
            for gamma in 0usize..=4 {
                let seed = 0xC0FFEE
                    ^ (vocab as u64)
                    ^ ((gamma as u64) << 32)
                    ^ (((alpha * 2.0) as u64) << 40);
                let mut gen = Rng::new(seed, 17);
                let mut rng_sparse = Rng::new(seed, 23);
                let mut rng_dense = Rng::new(seed, 23);
                // Dense expansion at 151936 is the expensive reference —
                // fewer rounds there keep the suite fast.
                let rounds = if vocab > 100_000 { 8 } else { 60 };
                for round in 0..rounds {
                    // Synthesize a round like the synthetic oracle: one-hot
                    // target chain, draft matching with probability α.
                    let targets: Vec<u32> = (0..=gamma)
                        .map(|_| gen.below(vocab as u64) as u32)
                        .collect();
                    let draft_tokens: Vec<u32> = (0..gamma)
                        .map(|g| {
                            if gen.bernoulli(alpha) {
                                targets[g]
                            } else {
                                let mut t = gen.below(vocab as u64 - 1) as u32;
                                if t >= targets[g] {
                                    t += 1;
                                }
                                t
                            }
                        })
                        .collect();
                    let sparse_d: Vec<LogitsView> = draft_tokens
                        .iter()
                        .map(|&t| LogitsView::one_hot(t, vocab))
                        .collect();
                    let sparse_t: Vec<LogitsView> = targets
                        .iter()
                        .map(|&t| LogitsView::one_hot(t, vocab))
                        .collect();
                    let dense_d: Vec<Vec<f64>> =
                        sparse_d.iter().map(LogitsView::to_dense).collect();
                    let dense_t: Vec<Vec<f64>> =
                        sparse_t.iter().map(LogitsView::to_dense).collect();
                    let a =
                        verify_chain_views(&draft_tokens, &sparse_d, &sparse_t, &mut rng_sparse);
                    let b = verify_chain(&draft_tokens, &dense_d, &dense_t, &mut rng_dense);
                    assert_eq!(
                        a, b,
                        "sparse/dense divergence: vocab={vocab} α={alpha} γ={gamma} round={round}"
                    );
                }
                // Same number of RNG draws consumed on both paths.
                assert_eq!(
                    rng_sparse.next_u64(),
                    rng_dense.next_u64(),
                    "rng streams diverged: vocab={vocab} α={alpha} γ={gamma}"
                );
            }
        }
    }
}

/// Equivalence under arbitrary sparse supports: random TopK target rows
/// against full-support dense drafts (and dense-wrapped targets) match
/// the dense reference bit-for-bit.
#[test]
fn prop_topk_view_matches_dense_expansion() {
    let mut runner = Runner::new("topk_equivalence");
    runner.run(200, |g| {
        let vocab = g.usize_in(8, 512);
        let gamma = g.usize_in(0, 5);
        let k = g.usize_in(1, 8.min(vocab));
        let mut rng = Rng::seeded(g.u64_in(0, 1 << 30));
        // Random k-sparse target rows over distinct tokens.
        let mk_topk = |rng: &mut Rng| -> LogitsView {
            let mut ids: Vec<u32> = (0..vocab as u32).collect();
            rng.shuffle(&mut ids);
            let entries: Vec<(u32, f64)> =
                ids[..k].iter().map(|&t| (t, rng.f64() + 0.01)).collect();
            LogitsView::top_k(entries, vocab)
        };
        // Full-support dense draft rows.
        let mk_dense = |rng: &mut Rng| -> Vec<f64> {
            let v: Vec<f64> = (0..vocab).map(|_| rng.f64() + 0.01).collect();
            let s: f64 = v.iter().sum();
            v.into_iter().map(|x| x / s).collect()
        };
        let target_views: Vec<LogitsView> = (0..=gamma).map(|_| mk_topk(&mut rng)).collect();
        let draft_rows: Vec<Vec<f64>> = (0..gamma).map(|_| mk_dense(&mut rng)).collect();
        let draft_views: Vec<LogitsView> =
            draft_rows.iter().cloned().map(LogitsView::dense).collect();
        let draft_tokens: Vec<u32> = draft_rows
            .iter()
            .map(|d| rng.categorical(d) as u32)
            .collect();
        let dense_t: Vec<Vec<f64>> = target_views.iter().map(LogitsView::to_dense).collect();
        let seed = g.u64_in(0, 1 << 30);
        let mut ra = Rng::seeded(seed);
        let mut rb = Rng::seeded(seed);
        let a = verify_chain_views(&draft_tokens, &draft_views, &target_views, &mut ra);
        let b = verify_chain(&draft_tokens, &draft_rows, &dense_t, &mut rb);
        if a != b {
            return Err(format!("topk divergence: {a:?} vs {b:?} (vocab={vocab}, k={k})"));
        }
        if ra.next_u64() != rb.next_u64() {
            return Err("rng streams diverged".into());
        }
        ensure(true, "")
    });
}

/// Engine-level equivalence: a backend emitting sparse OneHot rows and the
/// dense-rows reference backend drive byte-identical serving runs — same
/// completions, same round count — at toy and realistic vocabulary.
#[test]
fn prop_engine_sparse_equals_dense_rows_backend() {
    for &(vocab, alpha, gamma) in &[(64usize, 0.5f64, 3usize), (4096, 0.9, 4), (151_936, 0.8, 2)] {
        let run = |dense: bool| -> (Vec<(u64, Vec<u32>)>, u64) {
            let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
            let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
            let mut backend = SyntheticLm::new(target, draft, alpha, 7).with_vocab(vocab);
            if dense {
                backend = backend.with_dense_rows();
            }
            let mut engine = Engine::new(
                EngineConfig {
                    gamma,
                    ..Default::default()
                },
                backend,
            );
            for id in 0..4u64 {
                engine.submit(Request {
                    id,
                    prompt: (0..6u32).collect(),
                    params: SamplingParams {
                        temperature: 0.0,
                        max_new_tokens: 8,
                        eos_token: None,
                    },
                    arrival: 0.0,
                    class: 0,
                });
            }
            let mut done = engine.run_to_completion(10_000).unwrap();
            done.sort_by_key(|c| c.id);
            (
                done.into_iter().map(|c| (c.id, c.tokens)).collect(),
                engine.metrics.rounds,
            )
        };
        let sparse = run(false);
        let dense = run(true);
        assert_eq!(sparse, dense, "vocab={vocab} α={alpha} γ={gamma}");
    }
}

/// The sharding equivalence tentpole guarantee: a `d = 1` [`ShardingSpec`]
/// — whether the explicit `single()` spec or a 1-rank fabric topology —
/// prices every (model, batch, verify width, context) point **bit-for-bit**
/// identically to the unsharded simulator, across MoE and dense targets,
/// expected and per-component breakdowns.
#[test]
fn prop_single_rank_sharding_prices_bit_identical() {
    let mut runner = Runner::new("sharding_d1_identity");
    runner.run(120, |g| {
        let moe = g.usize_in(0, 1) == 0;
        let arch = if moe {
            presets::qwen2_57b_a14b()
        } else {
            presets::opt_30b()
        };
        let b = g.usize_in(1, 1024);
        let s = g.usize_in(1, 8);
        let ctx = g.usize_in(16, 4096);
        let tiles = g.usize_in(0, 1) == 1;
        let plain = ExecSim::new(arch.clone(), platform_2x_gpu_a()).with_tile_effects(tiles);
        let single = ExecSim::new(arch.clone(), platform_2x_gpu_a())
            .with_tile_effects(tiles)
            .with_sharding(ShardingSpec::single());
        let one_rank = ExecSim::new(arch.clone(), platform_2x_gpu_a())
            .with_tile_effects(tiles)
            .with_sharding(ShardingSpec::for_arch(Topology::nvlink(1), &arch));
        let want = plain.forward_time(b, s, ctx, None);
        let got_single = single.forward_time(b, s, ctx, None);
        let got_one = one_rank.forward_time(b, s, ctx, None);
        if got_single != want {
            return Err(format!(
                "single() spec diverged at b={b} s={s} ctx={ctx} moe={moe}: {got_single:?} vs {want:?}"
            ));
        }
        if got_one != want {
            return Err(format!(
                "1-rank topology diverged at b={b} s={s} ctx={ctx} moe={moe}: {got_one:?} vs {want:?}"
            ));
        }
        // The memoized scalar path agrees too (same cache key space).
        ensure(
            single.t_forward(b, s, ctx) == plain.t_forward(b, s, ctx)
                && one_rank.t_reject(b, 3) == plain.t_reject(b, 3),
            "memoized/reject paths diverged",
        )
    });
}

/// Whole-engine d=1 equivalence: serving on a `single()`-sharded pricing
/// simulator emits byte-identical completions, round counts, and virtual
/// clocks to the unsharded engine.
#[test]
fn prop_engine_single_rank_sharding_is_transparent() {
    for &(alpha, gamma, n_reqs) in &[(0.5f64, 3usize, 4usize), (0.9, 5, 6), (0.0, 1, 2)] {
        let run = |sharded: bool| -> (Vec<(u64, Vec<u32>)>, u64, f64) {
            let arch = presets::qwen2_57b_a14b();
            let mut target = ExecSim::new(arch.clone(), platform_2x_gpu_a());
            if sharded {
                target = target.with_sharding(ShardingSpec::single());
            }
            let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
            let mut engine = Engine::new(
                EngineConfig {
                    gamma,
                    ..Default::default()
                },
                SyntheticLm::new(target, draft, alpha, 23),
            );
            for id in 0..n_reqs as u64 {
                engine.submit(Request {
                    id,
                    prompt: (0..8u32).collect(),
                    params: SamplingParams {
                        temperature: 0.0,
                        max_new_tokens: 12,
                        eos_token: None,
                    },
                    arrival: 0.0,
                    class: 0,
                });
            }
            let mut done = engine.run_to_completion(10_000).unwrap();
            done.sort_by_key(|c| c.id);
            (
                done.into_iter().map(|c| (c.id, c.tokens)).collect(),
                engine.metrics.rounds,
                engine.clock(),
            )
        };
        assert_eq!(run(false), run(true), "α={alpha} γ={gamma}");
    }
}

/// The ragged-pricing tentpole guarantee: packed token-count pricing with
/// uniform widths is **bit-for-bit** the scalar path — across MoE and
/// dense archs, tile effects, EP sharding, and the reject stage.
#[test]
fn prop_uniform_ragged_pricing_bit_identical() {
    let mut runner = Runner::new("ragged_uniform_pricing");
    runner.run(150, |g| {
        let moe = g.usize_in(0, 1) == 0;
        let arch = if moe {
            presets::qwen2_57b_a14b()
        } else {
            presets::opt_30b()
        };
        let b = g.usize_in(1, 512);
        let s = g.usize_in(1, 9);
        let ctx = g.usize_in(16, 2048);
        let tiles = g.usize_in(0, 1) == 1;
        let sharded = g.usize_in(0, 1) == 1;
        let mut sim = ExecSim::new(arch.clone(), platform_2x_gpu_a()).with_tile_effects(tiles);
        if sharded {
            sim = sim.with_sharding(ShardingSpec::for_arch(Topology::nvlink(4), &arch));
        }
        let widths = vec![s; b];
        if sim.t_forward_ragged(&widths, ctx) != sim.t_forward(b, s, ctx) {
            return Err(format!(
                "uniform ragged forward diverged: b={b} s={s} ctx={ctx} moe={moe} sharded={sharded}"
            ));
        }
        let gamma = s - 1;
        if sim.t_reject_rows(b * (gamma + 1)) != sim.t_reject(b, gamma) {
            return Err(format!("uniform ragged reject diverged: b={b} γ={gamma}"));
        }
        ensure(true, "")
    });
}

/// Whole-engine uniform-ragged transparency: per-sequence overrides that
/// all equal `config.gamma` drive the ragged code path yet reproduce the
/// plain scalar engine byte-for-byte — completions, rounds, virtual clock.
#[test]
fn prop_engine_uniform_overrides_are_transparent() {
    let mut runner = Runner::new("ragged_uniform_engine");
    runner.run(12, |g| {
        let alpha = g.f64_in(0.0, 1.0);
        let gamma = g.usize_in(0, 6);
        let n_reqs = g.usize_in(1, 8);
        let seed = g.u64_in(0, 1 << 20);
        let run = |with_overrides: bool| -> Result<(Vec<(u64, Vec<u32>)>, u64, f64), String> {
            let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
            let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
            let mut overrides = std::collections::HashMap::new();
            if with_overrides {
                for id in 0..n_reqs as u64 {
                    overrides.insert(id, gamma);
                }
            }
            let mut engine = Engine::new(
                EngineConfig {
                    gamma,
                    gamma_overrides: overrides,
                    ..Default::default()
                },
                SyntheticLm::new(target, draft, alpha, seed),
            );
            for id in 0..n_reqs as u64 {
                engine.submit(Request {
                    id,
                    prompt: (0..8u32).collect(),
                    params: SamplingParams {
                        temperature: 0.0,
                        max_new_tokens: 16,
                        eos_token: None,
                    },
                    arrival: 0.0,
                    class: 0,
                });
            }
            let mut done = engine
                .run_to_completion(50_000)
                .map_err(|e| format!("{e}"))?;
            done.sort_by_key(|c| c.id);
            Ok((
                done.into_iter().map(|c| (c.id, c.tokens)).collect(),
                engine.metrics.rounds,
                engine.clock(),
            ))
        };
        let plain = run(false)?;
        let ragged = run(true)?;
        ensure(
            plain == ragged,
            format!("uniform overrides diverged (α={alpha}, γ={gamma})"),
        )
    });
}

/// Genuinely ragged rounds stay lossless: random per-sequence depths and
/// mixed per-sequence α still emit every sequence's exact chain, with KV
/// conservation intact.
#[test]
fn prop_ragged_rounds_stay_lossless() {
    let mut runner = Runner::new("ragged_lossless");
    runner.run(15, |g| {
        let n_reqs = g.usize_in(2, 8);
        let seed = g.u64_in(0, 1 << 20);
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        let mut overrides = std::collections::HashMap::new();
        let mut alphas = Vec::new();
        for id in 0..n_reqs as u64 {
            overrides.insert(id, g.usize_in(0, 8));
            alphas.push((id, g.f64_in(0.0, 1.0)));
        }
        let backend = SyntheticLm::new(target, draft, 0.7, seed).with_seq_alphas(&alphas);
        let mut engine = Engine::new(
            EngineConfig {
                gamma: 3,
                gamma_overrides: overrides,
                ..Default::default()
            },
            backend,
        );
        let max_new = g.usize_in(1, 24);
        for id in 0..n_reqs as u64 {
            engine.submit(Request {
                id,
                prompt: (0..6u32).collect(),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: max_new,
                    eos_token: None,
                },
                arrival: 0.0,
                class: 0,
            });
        }
        let done = engine
            .run_to_completion(100_000)
            .map_err(|e| format!("{e}"))?;
        if done.len() != n_reqs {
            return Err(format!("{} of {n_reqs} completed", done.len()));
        }
        for c in &done {
            let expect = engine.backend().expected_chain(c.id, 6, max_new);
            if c.tokens != expect {
                return Err(format!("seq {}: ragged round broke losslessness", c.id));
            }
        }
        engine
            .kv()
            .check_invariants()
            .map_err(|e| format!("KV invariant: {e}"))?;
        ensure(true, "")
    });
}

/// Routing conservation: every token lands on exactly K distinct experts,
/// and the empirical activation stays within the binomial envelope of the
/// Eq. 8 expectation.
#[test]
fn prop_routing_conservation_and_mean() {
    let mut runner = Runner::new("routing");
    runner.run(40, |g| {
        let e = g.usize_in(2, 64);
        let k = g.usize_in(1, e);
        let t = g.u64_in(1, 128);
        let mut rng = Rng::seeded(g.u64_in(0, 1 << 30));
        let router = Router::balanced(e, k);
        let out = router.route(t, &mut rng);
        let total: u64 = out.tokens_per_expert.iter().sum();
        if total != t * k as u64 {
            return Err(format!("token-assignment conservation: {total} != {}", t * k as u64));
        }
        let emp = router.empirical_activation(t, 200, &mut rng);
        let expect = theory::expected_active_experts(e, k, t);
        // 200-trial mean within a generous CLT band.
        if (emp - expect).abs() > 0.15 * e as f64 {
            return Err(format!("N(t): empirical {emp} vs theory {expect} (E={e},K={k},t={t})"));
        }
        ensure(true, "")
    });
}

/// Eq. 4 sanity: modeled speedup is continuous and bounded by σ(γ+1)
/// (perfect verification/draft can't beat the round length).
#[test]
fn prop_speedup_bounded_by_round_length() {
    let mut runner = Runner::new("speedup_bound");
    runner.run(300, |g| {
        let t1 = g.f64_in(1e-3, 1.0);
        let tg = t1 * g.f64_in(1.0, 8.0);
        let td = t1 * g.f64_in(0.0, 0.5);
        let tr = t1 * g.f64_in(0.0, 0.1);
        let sigma = g.f64_in(0.2, 1.0);
        let gamma = g.usize_in(1, 6);
        let s = theory::speedup_decomposition(t1, tg, td, tr, sigma, gamma).speedup();
        let bound = sigma * (gamma + 1) as f64;
        ensure(
            s > 0.0 && s <= bound + 1e-9,
            format!("speedup {s} outside (0, {bound}]"),
        )
    });
}

/// The budget off-switch tentpole guarantee, forward level: `budget =
/// None` and any budget ≥ E (the whole expert pool, so `min(N(t), b)` is
/// a no-op) price every (model, batch, verify width, context) point
/// **bit-for-bit** identically to the unbudgeted path — across MoE and
/// dense targets, tile effects, uniform and ragged widths, and EP-sharded
/// simulators. On a dense target *any* budget is transparent (there is no
/// expert gate to cap).
#[test]
fn prop_budget_off_switch_prices_bit_identical() {
    let mut runner = Runner::new("budget_off_identity");
    runner.run(120, |g| {
        let moe = g.usize_in(0, 1) == 0;
        let arch = if moe {
            presets::qwen2_57b_a14b()
        } else {
            presets::opt_30b()
        };
        let b = g.usize_in(1, 512);
        let s = g.usize_in(1, 9);
        let ctx = g.usize_in(16, 2048);
        let tiles = g.usize_in(0, 1) == 1;
        let sharded = g.usize_in(0, 1) == 1;
        let mut sim = ExecSim::new(arch.clone(), platform_2x_gpu_a()).with_tile_effects(tiles);
        if sharded {
            sim = sim.with_sharding(ShardingSpec::for_arch(Topology::nvlink(4), &arch));
        }
        // Any budget covering the whole pool is the off switch; on a
        // dense arch even a tiny budget must be transparent.
        let big = match sim.moe_dims() {
            Some((e, _)) => e + g.usize_in(0, 512),
            None => g.usize_in(1, 512),
        };
        let off = sim.t_forward_tokens_budgeted(b, b * s, ctx, None);
        let capped = sim.t_forward_tokens_budgeted(b, b * s, ctx, Some(big));
        if off.to_bits() != capped.to_bits() {
            return Err(format!(
                "budget={big} diverged from None: b={b} s={s} ctx={ctx} moe={moe} \
                 sharded={sharded}: {capped} vs {off}"
            ));
        }
        // The plain (never-budgeted) entry points agree with budget=None.
        if sim.t_forward_tokens(b, b * s, ctx).to_bits() != off.to_bits()
            || sim.t_forward(b, s, ctx).to_bits() != off.to_bits()
        {
            return Err(format!(
                "budget=None diverged from the unbudgeted path: b={b} s={s} ctx={ctx}"
            ));
        }
        // Per-component breakdowns agree too (the rng-free expected path).
        let want = sim.forward_time_tokens_budgeted(b, b * s, ctx, None, None);
        let got = sim.forward_time_tokens_budgeted(b, b * s, ctx, None, Some(big));
        if got != want {
            return Err(format!(
                "breakdown diverged under budget={big}: b={b} s={s} ctx={ctx} moe={moe}"
            ));
        }
        // Ragged widths: same packed pricing, same off switch.
        let widths: Vec<usize> = (0..b.min(16))
            .map(|_| g.usize_in(1, 9))
            .collect();
        let r_off = sim.t_forward_ragged_budgeted(&widths, ctx, None);
        let r_cap = sim.t_forward_ragged_budgeted(&widths, ctx, Some(big));
        ensure(
            r_off.to_bits() == r_cap.to_bits()
                && r_off.to_bits() == sim.t_forward_ragged(&widths, ctx).to_bits(),
            format!("ragged budget off-switch diverged (moe={moe}, sharded={sharded})"),
        )
    });
}

/// A sub-pool budget on a MoE target must actually change the price once
/// the verify width activates more experts than the budget — the axis is
/// not vacuous — and can only make the forward cheaper (weight traffic
/// shrinks; FLOPs are unchanged).
#[test]
fn prop_budget_caps_are_monotone_nonvacuous() {
    let mut runner = Runner::new("budget_monotone");
    runner.run(80, |g| {
        let arch = presets::qwen2_57b_a14b();
        let sim = ExecSim::new(arch.clone(), platform_2x_gpu_a());
        let (e, k) = sim.moe_dims().expect("qwen2-57B-A14B is MoE");
        let b = g.usize_in(1, 64);
        let s = g.usize_in(2, 9);
        let ctx = g.usize_in(16, 2048);
        let tokens = b * s;
        let off = sim.t_forward_tokens_budgeted(b, tokens, ctx, None);
        let mut prev = off;
        for bud in [e * 3 / 4, e / 2, e / 4, e / 8] {
            let t = sim.t_forward_tokens_budgeted(b, tokens, ctx, Some(bud));
            if t > prev + 1e-15 {
                return Err(format!(
                    "price rose as budget tightened to {bud}: {t} > {prev} (b={b} s={s})"
                ));
            }
            prev = t;
        }
        // Non-vacuity: once N(t) clearly exceeds the tightest budget
        // *and* the expert FFN is still memory-bound (the cap trims
        // weight bytes only — at very large widths the op goes
        // compute-bound and the budget legitimately stops biting),
        // the cap must strictly lower the price.
        let n_unc = theory::expected_active_experts(e, k, tokens as u64);
        let tight = e / 8;
        if n_unc > tight as f64 + 1.0 && tokens <= 256 {
            let t = sim.t_forward_tokens_budgeted(b, tokens, ctx, Some(tight));
            if t >= off {
                return Err(format!(
                    "budget={tight} did not bite at tokens={tokens} (N(t)={n_unc:.1})"
                ));
            }
        }
        ensure(true, "")
    });
}

/// Whole-engine budget off-switch: a backend carrying the acceptance
/// degradation curve with the budget set to the full pool (or wider)
/// serves byte-identically to the plain backend — same completions,
/// rounds, and virtual clock. The curve only alters behaviour when the
/// budget actually undercuts expected activation.
#[test]
fn prop_engine_verify_budget_off_switch_is_transparent() {
    let mut runner = Runner::new("budget_engine_identity");
    runner.run(12, |g| {
        let alpha = g.f64_in(0.0, 1.0);
        let gamma = g.usize_in(0, 5);
        let n_reqs = g.usize_in(1, 8);
        let seed = g.u64_in(0, 1 << 20);
        let sens = g.f64_in(0.05, 1.0);
        let big = 64 + g.usize_in(0, 64); // ≥ E for qwen2-57B-A14B
        let run = |budgeted: bool| -> Result<(Vec<(u64, Vec<u32>)>, u64, f64), String> {
            let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
            let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
            let mut backend = SyntheticLm::new(target, draft, alpha, seed);
            if budgeted {
                backend = backend.with_budget_alpha_curve(sens);
                backend.set_verify_budget(Some(big));
            }
            let mut engine = Engine::new(
                EngineConfig {
                    gamma,
                    ..Default::default()
                },
                backend,
            );
            for id in 0..n_reqs as u64 {
                engine.submit(Request {
                    id,
                    prompt: (0..8u32).collect(),
                    params: SamplingParams {
                        temperature: 0.0,
                        max_new_tokens: 12,
                        eos_token: None,
                    },
                    arrival: 0.0,
                    class: 0,
                });
            }
            let mut done = engine
                .run_to_completion(50_000)
                .map_err(|e| format!("{e}"))?;
            done.sort_by_key(|c| c.id);
            Ok((
                done.into_iter().map(|c| (c.id, c.tokens)).collect(),
                engine.metrics.rounds,
                engine.clock(),
            ))
        };
        let plain = run(false)?;
        let capped = run(true)?;
        ensure(
            plain == capped,
            format!("whole-pool budget {big} not transparent (α={alpha}, γ={gamma}, sens={sens})"),
        )
    });
}

/// The engine's measured σ always lies in Eq. 5's attainable range.
#[test]
fn prop_measured_sigma_in_eq5_range() {
    let mut runner = Runner::new("sigma_range");
    runner.run(12, |g| {
        let alpha = g.f64_in(0.05, 0.95);
        let gamma = g.usize_in(1, 5);
        let mut engine = mk_engine(alpha, gamma, 8, 2000, g.u64_in(0, 999));
        for id in 0..6u64 {
            engine.submit(Request {
                id,
                prompt: (0..8u32).collect(),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: 30,
                    eos_token: None,
                },
                arrival: 0.0,
                class: 0,
            });
        }
        engine
            .run_to_completion(100_000)
            .map_err(|e| format!("{e}"))?;
        let sigma = engine.metrics.sigma(gamma);
        let lo = 1.0 / (gamma + 1) as f64;
        ensure(
            sigma >= lo - 1e-9 && sigma <= 1.0 + 1e-9,
            format!("σ {sigma} outside [{lo}, 1] (α={alpha}, γ={gamma})"),
        )
    });
}
