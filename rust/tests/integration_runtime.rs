//! Integration tests over the PJRT runtime + real AOT artifacts.
//!
//! These require `make artifacts` to have run (they skip gracefully
//! otherwise, so `cargo test` works on a clean checkout, but CI runs the
//! full pipeline).

use moesd::batching::{Request, SamplingParams};
use moesd::engine::{Engine, EngineConfig};
use moesd::kvcache::KvConfig;
use moesd::runtime::hlo_model::HloBackend;
use moesd::runtime::{Manifest, PjrtEngine};
use moesd::spec::SdBackend;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn pjrt_compiles_and_runs_an_artifact() {
    let Some(dir) = artifacts() else { return };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    let m = engine.manifest().clone();
    assert!(m.buckets.contains(&1));
    // Compiling twice returns the cached executable.
    engine.executable("target", 1, 1).unwrap();
    assert_eq!(engine.compiled_count(), 1);
    engine.executable("target", 1, 1).unwrap();
    assert_eq!(engine.compiled_count(), 1);
}

#[test]
fn numerics_match_python_reference() {
    // The AOT round-trip gate: rust PJRT execution reproduces the logits
    // python computed with the same weights through the pallas path.
    let Some(dir) = artifacts() else { return };
    let mut backend = HloBackend::new(&dir).unwrap();
    backend.self_check().unwrap();
}

#[test]
fn manifest_consistent_with_weights() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    let w = moesd::runtime::weights::Weights::load(&dir.join("weights.bin")).unwrap();
    assert_eq!(m.target.vocab, 256);
    // Embedding shape matches the manifest dims.
    let emb = w.get("target.embed").unwrap();
    assert_eq!(emb.dims, vec![m.target.vocab, m.target.hidden]);
    let demb = w.get("draft.embed").unwrap();
    assert_eq!(demb.dims, vec![m.draft.vocab, m.draft.hidden]);
}

#[test]
fn greedy_decode_is_deterministic_and_incremental() {
    let Some(dir) = artifacts() else { return };
    let mut b = HloBackend::new(&dir).unwrap();
    let prompt = moesd::tokenizer::encode("INFO GET /api", true);

    // AR-decode 6 tokens greedily (γ=0 protocol: verify(feed, [])).
    let mut decode = |backend: &mut HloBackend, id: u64| -> Vec<u32> {
        backend.prefill(&[(id, prompt.clone())]).unwrap();
        let mut stream = prompt.clone();
        let mut base = prompt.len() - 1;
        let mut out = Vec::new();
        for _ in 0..6 {
            let v = backend
                .verify(&[id], &[stream[base]], &[vec![]], &[0.0])
                .unwrap();
            // Greedy rows come back as sparse views; argmax is the token.
            let tok = v.probs[0][0].argmax();
            stream.push(tok);
            out.push(tok);
            base += 1;
        }
        backend.release(id);
        out
    };
    let a = decode(&mut b, 1);
    let c = decode(&mut b, 2);
    assert_eq!(a, c, "greedy decoding must be deterministic");
}

#[test]
fn sd_equals_ar_end_to_end_on_real_model() {
    // THE losslessness test on the real stack: same engine, same request,
    // γ=3 (speculative) vs γ=0 (autoregressive), greedy sampling — the
    // emitted tokens must be identical.
    let Some(dir) = artifacts() else { return };
    let run = |gamma: usize| -> Vec<Vec<u32>> {
        let backend = HloBackend::new(&dir).unwrap();
        let config = EngineConfig {
            gamma,
            kv: KvConfig {
                num_blocks: 256,
                block_size: 16,
            },
            ..Default::default()
        };
        let mut engine = Engine::new(config, backend);
        for (i, text) in ["INFO GET /api", "DEBUG expert[3]", "INFO worker=2 qu"]
            .iter()
            .enumerate()
        {
            engine.submit(Request {
                id: i as u64,
                prompt: moesd::tokenizer::encode(text, true),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: 24,
                    eos_token: None,
                },
                arrival: 0.0,
                class: 0,
            });
        }
        let mut done = engine.run_to_completion(200).unwrap();
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.tokens).collect()
    };
    let sd = run(3);
    let ar = run(0);
    assert_eq!(sd, ar, "speculative decoding must be lossless");
    // And the generations are non-trivial (trained model, not noise).
    assert!(sd.iter().all(|t| t.len() == 24));
}

#[test]
fn trained_draft_gets_useful_acceptance() {
    // The draft was trained on the same corpus: acceptance on structured
    // prompts should be far above the 1/vocab ≈ 0.4% random-guess floor.
    let Some(dir) = artifacts() else { return };
    let backend = HloBackend::new(&dir).unwrap();
    let mut engine = Engine::new(
        EngineConfig {
            gamma: 3,
            kv: KvConfig {
                num_blocks: 512,
                block_size: 16,
            },
            ..Default::default()
        },
        backend,
    );
    for (i, text) in [
        "INFO GET /api/v1/users 200 OK in ",
        "INFO PUT /api/v1/items 404 ",
        "DEBUG expert[5] load=",
        "INFO worker=3 queue=",
    ]
    .iter()
    .enumerate()
    {
        engine.submit(Request {
            id: i as u64,
            prompt: moesd::tokenizer::encode(text, true),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 32,
                eos_token: None,
            },
            arrival: 0.0,
            class: 0,
        });
    }
    engine.run_to_completion(300).unwrap();
    let alpha = engine.metrics.acceptance_rate();
    assert!(
        alpha > 0.2,
        "trained draft should be accepted often: α={alpha}"
    );
    let sigma = engine.metrics.sigma(3);
    assert!(sigma > 0.3, "σ={sigma}");
}

#[test]
fn kv_overflow_is_an_error_not_corruption() {
    let Some(dir) = artifacts() else { return };
    let mut b = HloBackend::new(&dir).unwrap();
    let kv_max = b.manifest().target.kv_max;
    let prompt: Vec<u32> = (0..kv_max as u32 + 8).map(|i| 2 + (i % 250)).collect();
    assert!(b.prefill(&[(1, prompt)]).is_err());
}
