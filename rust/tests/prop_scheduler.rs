//! Property tests for the admission-policy contracts (ISSUE 5 satellite):
//! every [`AdmissionPolicy`] respects the KV/ceiling contracts, FIFO
//! order survives within a class, aging bounds starvation, and the
//! class-aware policy with one class degenerates to the FIFO baseline
//! bit-for-bit — at the scheduler level AND through a whole engine run.

use moesd::arch::presets;
use moesd::batching::{Request, RequestQueue, SamplingParams};
use moesd::engine::{Engine, EngineConfig};
use moesd::hardware::platform_2x_gpu_a;
use moesd::kvcache::{KvConfig, KvManager};
use moesd::scheduler::{
    AdmissionContext, AdmissionPolicyConfig, ClassAwareConfig, RunningInfo, Scheduler,
    SchedulerConfig,
};
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::testkit::{ensure, Gen, Runner};
use moesd::workload::TenantClass;

fn req(id: u64, prompt_len: usize, class: usize, arrival: f64) -> Request {
    Request {
        id,
        prompt: vec![1; prompt_len.max(1)],
        params: SamplingParams::default(),
        arrival,
        class,
    }
}

/// A random tenant table: 1–4 classes with random priorities/weights and
/// occasional per-class running caps.
fn gen_tenants(g: &mut Gen) -> Vec<TenantClass> {
    let n = g.usize_in(1, 4);
    (0..n)
        .map(|i| {
            let mut t = TenantClass::new(&format!("c{i}"));
            t.priority = g.usize_in(1, 3) as u32;
            t.weight = g.f64_in(0.5, 4.0);
            if g.bool() {
                t.max_running = Some(g.usize_in(1, 8));
            }
            if g.bool() {
                t.alpha_hint = Some(g.prob());
            }
            t
        })
        .collect()
}

fn gen_queue(g: &mut Gen, n_classes: usize) -> RequestQueue {
    let mut q = RequestQueue::new();
    let n = g.usize_in(0, 24);
    let mut t = 0.0;
    for id in 0..n as u64 {
        t += g.f64_in(0.0, 0.5);
        q.push(req(id, g.usize_in(1, 60), g.usize_in(0, n_classes - 1), t));
    }
    q
}

#[test]
fn prop_admission_respects_ceiling_kv_and_class_caps() {
    let mut runner = Runner::new("admission_contracts");
    runner.run(120, |g| {
        let tenants = gen_tenants(g);
        let mut q = gen_queue(g, tenants.len());
        let queued_before: Vec<(u64, usize)> = q.iter().map(|r| (r.id, r.class)).collect();
        let kv = KvManager::new(KvConfig {
            num_blocks: g.usize_in(1, 64),
            block_size: g.usize_in(4, 16),
        });
        let running: Vec<RunningInfo> = (0..g.usize_in(0, 6))
            .map(|_| RunningInfo {
                class: g.usize_in(0, tenants.len() - 1),
                alpha: g.bool().then(|| g.prob()),
            })
            .collect();
        let config = SchedulerConfig {
            max_batch: g.usize_in(0, 16),
            admit_reserve_tokens: g.usize_in(0, 32),
            tpot_slo: None,
        };
        let ceiling = g.usize_in(0, 20);
        let now = g.f64_in(0.0, 14.0);
        let class_ceilings: Option<Vec<usize>> = g
            .bool()
            .then(|| (0..tenants.len()).map(|_| g.usize_in(0, 10)).collect());
        let policy = if g.bool() {
            AdmissionPolicyConfig::Fifo
        } else {
            AdmissionPolicyConfig::ClassAware(ClassAwareConfig {
                aging_tau: *g.pick(&[2.0, 30.0, f64::INFINITY]),
                ..ClassAwareConfig::default()
            })
        };
        let mut s = Scheduler::with_policy(config.clone(), &policy);
        let ctx = AdmissionContext {
            kv: &kv,
            running: &running,
            ceiling,
            now,
            tenants: &tenants,
            class_ceilings: class_ceilings.as_deref(),
            oracle: None,
        };
        let admitted = s.admit_with(&mut q, &ctx);

        // Ceiling contract: running + admitted within min(ceiling, max_batch).
        if running.len() + admitted.len() > ceiling.min(config.max_batch) && !admitted.is_empty() {
            return ensure(false, "ceiling exceeded");
        }
        // KV contract: total reserved blocks fit the free pool.
        let bs = kv.config().block_size;
        let need: usize = admitted
            .iter()
            .map(|r| (r.prompt.len() + config.admit_reserve_tokens).div_ceil(bs))
            .sum();
        if need > kv.free_blocks() {
            return ensure(false, format!("KV over-reserved: {need} > {}", kv.free_blocks()));
        }
        // No future arrivals.
        if admitted.iter().any(|r| r.arrival > now) {
            return ensure(false, "admitted a future arrival");
        }
        // Per-class caps (only the class-aware policy promises these).
        if let (AdmissionPolicyConfig::ClassAware(_), Some(cc)) = (&policy, &class_ceilings) {
            for (c, t) in tenants.iter().enumerate() {
                let total = running.iter().filter(|r| r.class == c).count()
                    + admitted.iter().filter(|r| r.class == c).count();
                let cap = t.max_running.unwrap_or(usize::MAX).min(cc[c]);
                // Running alone may already exceed a cap; admission must
                // not add to a class at/over its cap.
                let was = running.iter().filter(|r| r.class == c).count();
                if total > cap.max(was) {
                    return ensure(false, format!("class {c} cap {cap} exceeded: {total}"));
                }
            }
        }
        // Conservation: admitted ∪ remaining == original queue, id-exact.
        let mut seen: Vec<(u64, usize)> = admitted.iter().map(|r| (r.id, r.class)).collect();
        seen.extend(q.iter().map(|r| (r.id, r.class)));
        seen.sort();
        let mut want = queued_before.clone();
        want.sort();
        if seen != want {
            return ensure(false, "requests lost or duplicated by admission");
        }
        // FIFO within class: each class's admitted ids appear in the same
        // order as they were queued.
        for c in 0..tenants.len() {
            let admitted_c: Vec<u64> = admitted
                .iter()
                .filter(|r| r.class == c)
                .map(|r| r.id)
                .collect();
            let queued_c: Vec<u64> = queued_before
                .iter()
                .filter(|(_, rc)| *rc == c)
                .map(|(id, _)| *id)
                .collect();
            let mut cursor = 0usize;
            for id in &admitted_c {
                match queued_c[cursor..].iter().position(|q| q == id) {
                    Some(ofs) => cursor += ofs + 1,
                    None => return ensure(false, format!("class {c}: order violated")),
                }
            }
        }
        ensure(true, "")
    });
}

#[test]
fn prop_one_class_class_aware_is_fifo_bit_for_bit() {
    let mut runner = Runner::new("one_class_degeneracy");
    runner.run(150, |g| {
        let config = SchedulerConfig {
            max_batch: g.usize_in(0, 12),
            admit_reserve_tokens: g.usize_in(0, 24),
            tpot_slo: None,
        };
        let kv = KvManager::new(KvConfig {
            num_blocks: g.usize_in(1, 48),
            block_size: g.usize_in(2, 16),
        });
        let running_n = g.usize_in(0, 6);
        let ceiling = g.usize_in(0, 16);
        let now = g.f64_in(0.0, 8.0);
        let mk_queue = |g: &mut Gen| {
            let mut q = RequestQueue::new();
            let n = g.usize_in(0, 20);
            let mut t = 0.0;
            for id in 0..n as u64 {
                t += g.f64_in(0.0, 1.0);
                q.push(req(id, g.usize_in(1, 80), 0, t));
            }
            q
        };
        let q_spec: Vec<(u64, usize, f64)> = {
            let q = mk_queue(g);
            q.iter().map(|r| (r.id, r.prompt.len(), r.arrival)).collect()
        };
        let rebuild = |spec: &[(u64, usize, f64)]| {
            let mut q = RequestQueue::new();
            for &(id, len, arrival) in spec {
                q.push(req(id, len, 0, arrival));
            }
            q
        };
        let mut fifo = Scheduler::with_policy(config.clone(), &AdmissionPolicyConfig::Fifo);
        let mut cls = Scheduler::with_policy(
            config.clone(),
            &AdmissionPolicyConfig::ClassAware(ClassAwareConfig::default()),
        );
        let running = vec![
            RunningInfo {
                class: 0,
                alpha: None,
            };
            running_n
        ];
        let mut qa = rebuild(&q_spec);
        let mut qb = rebuild(&q_spec);
        let ctx = AdmissionContext::simple(&kv, &running, ceiling, now);
        let a = fifo.admit_with(&mut qa, &ctx);
        let b = cls.admit_with(&mut qb, &ctx);
        let ids = |v: &[Request]| v.iter().map(|r| r.id).collect::<Vec<_>>();
        if ids(&a) != ids(&b) {
            return ensure(false, format!("admission diverged: {:?} vs {:?}", ids(&a), ids(&b)));
        }
        let rem = |q: &RequestQueue| q.iter().map(|r| r.id).collect::<Vec<_>>();
        ensure(rem(&qa) == rem(&qb), "remaining queues diverged")
    });
}

#[test]
fn prop_single_class_engine_runs_reproduce_fifo_bit_for_bit() {
    // The acceptance criterion: a single-class class-aware config
    // reproduces the pre-refactor engine behavior exactly — tokens,
    // virtual clock, rounds, preemptions — across random workloads
    // (including KV pressure that forces preemption).
    let mut runner = Runner::new("single_class_engine_degeneracy");
    runner.run(12, |g| {
        let alpha = g.f64_in(0.4, 0.95);
        let gamma = g.usize_in(0, 5);
        let max_batch = g.usize_in(1, 6);
        let blocks = g.usize_in(16, 64);
        let n_req = g.usize_in(1, 8);
        let seed = g.u64_in(0, 1 << 20);
        let lens: Vec<usize> = (0..n_req).map(|_| g.usize_in(2, 12)).collect();
        let news: Vec<usize> = (0..n_req).map(|_| g.usize_in(1, 24)).collect();
        let arrivals: Vec<f64> = {
            let mut t = 0.0;
            (0..n_req)
                .map(|_| {
                    t += g.f64_in(0.0, 0.05);
                    t
                })
                .collect()
        };
        let run = |admission: AdmissionPolicyConfig| -> (Vec<Vec<u32>>, u64, f64, u64) {
            let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
            let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
            let mut e = Engine::new(
                EngineConfig {
                    gamma,
                    kv: KvConfig {
                        num_blocks: blocks,
                        block_size: 4,
                    },
                    scheduler: SchedulerConfig {
                        max_batch,
                        admit_reserve_tokens: 4,
                        tpot_slo: None,
                    },
                    seed,
                    admission,
                    ..Default::default()
                },
                SyntheticLm::new(target, draft, alpha, seed),
            );
            for i in 0..n_req {
                e.submit(Request {
                    id: i as u64,
                    prompt: (0..lens[i] as u32).collect(),
                    params: SamplingParams {
                        temperature: 0.0,
                        max_new_tokens: news[i],
                        eos_token: None,
                    },
                    arrival: arrivals[i],
                    class: 0,
                });
            }
            let mut done = e.run_to_completion(20_000).expect("run completes");
            done.sort_by_key(|c| c.id);
            (
                done.into_iter().map(|c| c.tokens).collect(),
                e.metrics.rounds,
                e.clock(),
                e.counters.get("preemptions"),
            )
        };
        let fifo = run(AdmissionPolicyConfig::Fifo);
        let cls = run(AdmissionPolicyConfig::ClassAware(ClassAwareConfig::default()));
        ensure(
            fifo == cls,
            format!(
                "engine diverged: fifo (rounds {}, clock {}, preempt {}) vs class-aware \
                 (rounds {}, clock {}, preempt {})",
                fifo.1, fifo.2, fifo.3, cls.1, cls.2, cls.3
            ),
        )
    });
}

#[test]
fn aging_bounds_starvation_deterministically() {
    // A low-priority request facing an endless stream of fresh
    // high-priority work is admitted once its wait crosses the priority
    // gap × aging_tau — starvation is bounded, not just mitigated.
    let mut hi = TenantClass::new("hi");
    hi.priority = 3;
    let lo = TenantClass::new("lo"); // priority 1, gap = 2 tiers
    let tenants = vec![hi, lo];
    let tau = 5.0;
    let mut s = Scheduler::with_policy(
        SchedulerConfig {
            max_batch: 1,
            admit_reserve_tokens: 0,
            tpot_slo: None,
        },
        &AdmissionPolicyConfig::ClassAware(ClassAwareConfig {
            aging_tau: tau,
            ..ClassAwareConfig::default()
        }),
    );
    let kv = KvManager::new(KvConfig {
        num_blocks: 1024,
        block_size: 16,
    });
    let mut admitted_lo_at = None;
    let mut next_id = 100u64;
    let mut q = RequestQueue::new();
    q.push(req(0, 4, 1, 0.0)); // the starving low-priority request
    for step in 0..16 {
        let now = step as f64;
        // One fresh high-priority arrival per unit time.
        q.push(req(next_id, 4, 0, now));
        next_id += 1;
        let ctx = AdmissionContext {
            kv: &kv,
            running: &[],
            ceiling: 1,
            now,
            tenants: &tenants,
            class_ceilings: None,
            oracle: None,
        };
        for r in s.admit_with(&mut q, &ctx) {
            if r.class == 1 {
                admitted_lo_at = Some(now);
            }
        }
        if admitted_lo_at.is_some() {
            break;
        }
    }
    let when = admitted_lo_at.expect("aged request must eventually be admitted");
    // Gap of 2 tiers × τ=5 s → promoted at wait ≥ 10 s; fresh hi work
    // keeps winning before that.
    assert!(when >= 2.0 * tau, "admitted too early: {when}");
    assert!(when <= 2.0 * tau + 2.0, "admitted too late: {when}");
}
