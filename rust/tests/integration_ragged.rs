//! End-to-end tests of ragged speculation (per-sequence γᵢ): the
//! bimodal-α goodput comparison the issue's acceptance criteria name,
//! losslessness of ragged rounds through the full engine, and the online
//! ragged control loop learning per-sequence α̂ᵢ.

use std::collections::HashMap;

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::control::{ControlConfig, CostModelSpec};
use moesd::engine::{Engine, EngineConfig};
use moesd::experiments::ragged;
use moesd::hardware::{platform_2x_gpu_a, Platform};
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;

fn sims() -> (ExecSim, ExecSim) {
    let platform = platform_2x_gpu_a();
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform.clone());
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = ExecSim::new(presets::qwen2_0_5b(), draft_platform);
    (target, draft)
}

fn req(id: u64, max_new: usize) -> Request {
    Request {
        id,
        prompt: (0..12u32).collect(),
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: max_new,
            eos_token: None,
        },
        arrival: 0.0,
        class: 0,
    }
}

/// The acceptance criterion: ragged-γ goodput ≥ best uniform-γ on a
/// bimodal-α sweep (reduced grid; the full grid runs in
/// `moesd bench ragged`).
#[test]
fn ragged_beats_best_uniform_on_bimodal_sweep() {
    let out = ragged::run(&[(0.9, 0.5)], &[8, 32], &[8], 21).unwrap();
    ragged::check_shape(&out).unwrap();
}

/// Ragged rounds stay lossless under the full online loop: an adaptive
/// ragged controller on a bimodal population still emits every sequence's
/// exact deterministic chain.
#[test]
fn adaptive_ragged_rounds_are_lossless() {
    let (tsim, dsim) = sims();
    let control = ControlConfig {
        seq_window_rounds: 4,
        ..ControlConfig::model_guided_ragged(CostModelSpec::roofline(tsim.clone(), dsim.clone()))
    };
    let backend = SyntheticLm::new(tsim, dsim, 0.9, 31)
        .with_seq_alphas(&[(1, 0.4), (3, 0.4), (5, 0.4)]);
    let config = EngineConfig {
        gamma: 0,
        control: Some(control),
        ..Default::default()
    };
    let mut engine = Engine::new(config, backend);
    for id in 0..6u64 {
        engine.submit(req(id, 40));
    }
    let done = engine.run_to_completion(5000).unwrap();
    assert_eq!(done.len(), 6);
    for c in &done {
        assert_eq!(
            c.tokens,
            engine.backend().expected_chain(c.id, 12, 40),
            "seq {} lost losslessness under ragged rounds",
            c.id
        );
    }
    let st = engine.controller_state().unwrap();
    assert!(
        st.ragged_rounds > 0,
        "bimodal population should trigger ragged rounds: {st:?}"
    );
}

/// The online windows actually separate the two classes: after enough
/// rounds the controller's per-sequence α̂ᵢ for an easy and a hard
/// long-running sequence straddle the truth.
#[test]
fn online_windows_learn_per_sequence_alpha() {
    let (tsim, dsim) = sims();
    let control = ControlConfig {
        seq_window_rounds: 6,
        ..ControlConfig::model_guided_ragged(CostModelSpec::roofline(tsim.clone(), dsim.clone()))
    };
    let backend = SyntheticLm::new(tsim, dsim, 0.95, 7).with_seq_alphas(&[(1, 0.3)]);
    let config = EngineConfig {
        gamma: 0,
        control: Some(control),
        ..Default::default()
    };
    let mut engine = Engine::new(config, backend);
    engine.submit(req(0, 600)); // easy, α = 0.95
    engine.submit(req(1, 600)); // hard, α = 0.3
    for _ in 0..80 {
        if engine.is_idle() {
            break;
        }
        engine.step().unwrap();
    }
    let ctl = engine.controller().unwrap();
    let easy = ctl.seq_alpha_hat(0).expect("easy window full");
    let hard = ctl.seq_alpha_hat(1).expect("hard window full");
    assert!(
        easy > 0.7 && easy > hard + 0.15,
        "windows should separate the classes: easy α̂={easy:.2} hard α̂={hard:.2}"
    );
}

/// Static ragged overrides compose with preemption and tiny KV caches:
/// per-sequence reservations (γᵢ+1) keep the engine correct under
/// capacity pressure.
#[test]
fn ragged_overrides_survive_capacity_pressure() {
    use moesd::kvcache::KvConfig;
    use moesd::scheduler::SchedulerConfig;
    let (tsim, dsim) = sims();
    let backend = SyntheticLm::new(tsim, dsim, 0.9, 13).with_seq_alphas(&[(1, 0.5), (3, 0.5)]);
    let mut overrides = HashMap::new();
    for id in 0..4u64 {
        overrides.insert(id, if id % 2 == 0 { 7 } else { 1 });
    }
    let config = EngineConfig {
        gamma: 3,
        gamma_overrides: overrides,
        kv: KvConfig {
            num_blocks: 16,
            block_size: 4,
        },
        scheduler: SchedulerConfig {
            max_batch: 4,
            admit_reserve_tokens: 4,
            tpot_slo: None,
        },
        ..Default::default()
    };
    let mut engine = Engine::new(config, backend);
    for id in 0..4u64 {
        engine.submit(req(id, 20));
    }
    let done = engine.run_to_completion(20_000).unwrap();
    assert_eq!(done.len(), 4);
    for c in &done {
        assert_eq!(c.tokens, engine.backend().expected_chain(c.id, 12, 20));
    }
    engine.kv().check_invariants().unwrap();
}
