//! Distributed-engine conformance properties (PR 9):
//!
//! 1. **Single-rank parity** — `Engine<DistBackend<SyntheticLm>>` with
//!    one verify rank on the loopback fabric reproduces the
//!    single-process `Engine<SyntheticLm>` bit-for-bit: same tokens,
//!    same virtual clock, same rounds/preemptions, same per-stage time
//!    accounting, across random workloads.
//! 2. **Rank-count invariance** — the same holds for d ∈ {2, 4} verify
//!    ranks (replicated verify + max-combined costs + 0.0 loopback hop
//!    is exactly the single-rank arithmetic).
//! 3. **Pipeline composition** — parity survives the full continuous
//!    pipeline (chunked prefill, draft-ahead, per-seq boundaries),
//!    ragged per-sequence γ overrides, and a static verify budget.
//! 4. **Sharded fabric** — a non-loopback fabric keeps tokens identical
//!    and only moves the clock (forward), by pricing the verify fan-out
//!    hop with `ShardingSpec::comm_time`.
//!
//! PR 10 adds the hot-path overhaul properties:
//!
//! 5. **Pipelining is pure latency** — overlapped in-flight ops produce
//!    a run bit-for-bit identical to draining after every op (serial),
//!    for every verify-rank count × draft-replica count.
//! 6. **Compaction is invisible** — a tiny op-log window forces many
//!    snapshot+truncate cycles and still reproduces the single-process
//!    run exactly, while bounding the log.
//! 7. **Draft scale-out is lossless** — striped propose across N draft
//!    replicas may re-price the clock (max-combined stripe costs) but
//!    the emitted tokens are still the deterministic oracle chains.
//!
//! Mirrors the PR-7 features-off ≡ lock-step suite: same workload
//! generator, same fingerprint.

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::dist::{DistBackend, DistConfig, DistFabric};
use moesd::engine::{Engine, EngineConfig, PipelineConfig};
use moesd::hardware::{platform_2x_gpu_a, ShardingSpec, Topology};
use moesd::kvcache::KvConfig;
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::spec::SdBackend;
use moesd::testkit::{ensure, Gen, Runner};
use std::collections::HashMap;

/// A random open-loop workload: staggered arrivals, random lengths.
struct Workload {
    alpha: f64,
    gamma: usize,
    max_batch: usize,
    blocks: usize,
    seed: u64,
    specs: Vec<(usize, usize, f64)>, // (prompt_len, max_new, arrival)
}

fn gen_workload(g: &mut Gen) -> Workload {
    let n_req = g.usize_in(1, 8);
    let mut t = 0.0;
    let specs = (0..n_req)
        .map(|_| {
            t += g.f64_in(0.0, 0.05);
            (g.usize_in(2, 12), g.usize_in(1, 24), t)
        })
        .collect();
    Workload {
        alpha: g.f64_in(0.4, 0.95),
        gamma: g.usize_in(0, 5),
        max_batch: g.usize_in(1, 6),
        blocks: g.usize_in(16, 64),
        seed: g.u64_in(0, 1 << 20),
        specs,
    }
}

fn synthetic(w: &Workload) -> SyntheticLm {
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    SyntheticLm::new(target, draft, w.alpha, w.seed)
}

fn engine_config(
    w: &Workload,
    pipeline: PipelineConfig,
    overrides: HashMap<u64, usize>,
) -> EngineConfig {
    EngineConfig {
        gamma: w.gamma,
        kv: KvConfig {
            num_blocks: w.blocks,
            block_size: 4,
        },
        scheduler: SchedulerConfig {
            max_batch: w.max_batch,
            admit_reserve_tokens: 4,
            tpot_slo: None,
        },
        seed: w.seed,
        pipeline,
        gamma_overrides: overrides,
        ..Default::default()
    }
}

fn submit_all<B: SdBackend>(e: &mut Engine<B>, w: &Workload) {
    for (i, &(prompt_len, max_new, arrival)) in w.specs.iter().enumerate() {
        e.submit(Request {
            id: i as u64,
            prompt: (0..prompt_len as u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: max_new,
                eos_token: None,
            },
            arrival,
            class: 0,
        });
    }
}

fn dist_backend_with(w: &Workload, cfg: DistConfig) -> DistBackend<SyntheticLm> {
    let (alpha, seed) = (w.alpha, w.seed);
    let factory = move || -> anyhow::Result<SyntheticLm> {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        Ok(SyntheticLm::new(target, draft, alpha, seed))
    };
    DistBackend::launch(cfg, factory).expect("dist launch")
}

fn dist_backend(w: &Workload, ranks: usize, fabric: DistFabric) -> DistBackend<SyntheticLm> {
    dist_backend_with(
        w,
        DistConfig {
            verify_ranks: ranks,
            fabric,
            ..Default::default()
        },
    )
}

/// Everything the parity claim compares: per-request outcomes, virtual
/// clock, round/preemption counts, and the stage-time accounting.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    completions: Vec<(u64, Vec<u32>, f64, f64)>, // (id, tokens, ttft, finished_at)
    rounds: u64,
    clock: f64,
    preemptions: u64,
    time_draft: f64,
    time_verify: f64,
    time_reject: f64,
    time_prefill: f64,
}

fn fingerprint<B: SdBackend>(e: &mut Engine<B>) -> Result<Fingerprint, String> {
    let mut done = e
        .run_to_completion(40_000)
        .map_err(|err| format!("run failed: {err}"))?;
    done.sort_by_key(|c| c.id);
    Ok(Fingerprint {
        completions: done
            .into_iter()
            .map(|c| (c.id, c.tokens, c.ttft(), c.finished_at))
            .collect(),
        rounds: e.metrics.rounds,
        clock: e.clock(),
        preemptions: e.counters.get("preemptions"),
        time_draft: e.metrics.time_draft,
        time_verify: e.metrics.time_verify,
        time_reject: e.metrics.time_reject,
        time_prefill: e.metrics.time_prefill,
    })
}

fn diverged(what: &str, single: &Fingerprint, dist: &Fingerprint) -> String {
    format!(
        "{what} diverged:\n  expected: rounds {} clock {} preempt {} \
         draft {} verify {} reject {} prefill {}\n  actual:   rounds {} clock {} preempt {} \
         draft {} verify {} reject {} prefill {}",
        single.rounds,
        single.clock,
        single.preemptions,
        single.time_draft,
        single.time_verify,
        single.time_reject,
        single.time_prefill,
        dist.rounds,
        dist.clock,
        dist.preemptions,
        dist.time_draft,
        dist.time_verify,
        dist.time_reject,
        dist.time_prefill,
    )
}

/// Run the same workload single-process and distributed; both
/// fingerprints must be identical (bit-for-bit: `PartialEq` on `f64`).
fn check_parity(
    w: &Workload,
    pipeline: PipelineConfig,
    overrides: HashMap<u64, usize>,
    ranks: usize,
    what: &str,
) -> Result<(), String> {
    let mut single = Engine::new(
        engine_config(w, pipeline.clone(), overrides.clone()),
        synthetic(w),
    );
    submit_all(&mut single, w);
    let fp_single = fingerprint(&mut single)?;

    let mut dist = Engine::new(
        engine_config(w, pipeline, overrides),
        dist_backend(w, ranks, DistFabric::Loopback),
    );
    submit_all(&mut dist, w);
    let fp_dist = fingerprint(&mut dist)?;

    if fp_single != fp_dist {
        return Err(diverged(what, &fp_single, &fp_dist));
    }
    // Losslessness doubly pinned: the distributed tokens are the
    // deterministic oracle chains, not merely "the same mistake twice".
    let reference = synthetic(w);
    for (i, (id, tokens, _, _)) in fp_dist.completions.iter().enumerate() {
        let (prompt_len, max_new, _) = w.specs[*id as usize];
        if tokens.len() != max_new {
            return Err(format!("seq {i}: {} tokens != {max_new}", tokens.len()));
        }
        if *tokens != reference.expected_chain(*id, prompt_len, max_new) {
            return Err(format!("seq {id}: dist tokens diverge from oracle chain"));
        }
    }
    Ok(())
}

#[test]
fn prop_dist_single_rank_reproduces_lockstep_bit_for_bit() {
    let mut runner = Runner::new("dist_single_rank_parity");
    runner.run(10, |g| {
        let w = gen_workload(g);
        check_parity(
            &w,
            PipelineConfig::default(),
            HashMap::new(),
            1,
            "dist(d=1, lockstep)",
        )?;
        ensure(true, "")
    });
}

#[test]
fn prop_dist_multi_rank_loopback_is_rank_count_invariant() {
    let mut runner = Runner::new("dist_multi_rank_parity");
    runner.run(8, |g| {
        let w = gen_workload(g);
        let d = *g.pick(&[2usize, 4]);
        check_parity(
            &w,
            PipelineConfig::default(),
            HashMap::new(),
            d,
            "dist(d>1, lockstep)",
        )?;
        ensure(true, "")
    });
}

#[test]
fn prop_dist_parity_survives_the_continuous_pipeline() {
    let mut runner = Runner::new("dist_continuous_parity");
    runner.run(8, |g| {
        let w = gen_workload(g);
        let chunk = g.usize_in(1, 16);
        let d = g.usize_in(1, 3);
        check_parity(
            &w,
            PipelineConfig::full(chunk),
            HashMap::new(),
            d,
            "dist(full continuous pipeline)",
        )?;
        ensure(true, "")
    });
}

#[test]
fn prop_dist_parity_survives_ragged_gamma_overrides() {
    let mut runner = Runner::new("dist_ragged_parity");
    runner.run(8, |g| {
        let w = gen_workload(g);
        // Ragged γ⃗: a random per-sequence depth for every request.
        let overrides: HashMap<u64, usize> = (0..w.specs.len() as u64)
            .map(|id| (id, g.usize_in(0, 6)))
            .collect();
        let d = g.usize_in(1, 2);
        check_parity(
            &w,
            PipelineConfig::default(),
            overrides,
            d,
            "dist(ragged gamma overrides)",
        )?;
        ensure(true, "")
    });
}

#[test]
fn dist_parity_with_static_verify_budget() {
    // Budgeted verify changes both cost and acceptance; the budget value
    // travels inside each VerifyReq frame, so the distributed run must
    // still match bit-for-bit.
    let w = Workload {
        alpha: 0.85,
        gamma: 4,
        max_batch: 4,
        blocks: 48,
        seed: 1234,
        specs: vec![(6, 16, 0.0), (4, 12, 0.01), (9, 20, 0.02)],
    };
    let mut single = Engine::new(
        engine_config(&w, PipelineConfig::default(), HashMap::new()),
        {
            let mut b = synthetic(&w).with_budget_alpha_curve(1.0);
            b.set_verify_budget(Some(16));
            b
        },
    );
    submit_all(&mut single, &w);
    let fp_single = fingerprint(&mut single).unwrap();

    let (alpha, seed) = (w.alpha, w.seed);
    let factory = move || -> anyhow::Result<SyntheticLm> {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        Ok(SyntheticLm::new(target, draft, alpha, seed).with_budget_alpha_curve(1.0))
    };
    let mut backend = DistBackend::launch(
        DistConfig {
            verify_ranks: 2,
            ..Default::default()
        },
        factory,
    )
    .unwrap();
    backend.set_verify_budget(Some(16));
    let mut dist = Engine::new(
        engine_config(&w, PipelineConfig::default(), HashMap::new()),
        backend,
    );
    submit_all(&mut dist, &w);
    let fp_dist = fingerprint(&mut dist).unwrap();
    assert_eq!(
        fp_single, fp_dist,
        "budgeted distributed run diverged from single-process"
    );
}

#[test]
fn prop_sharded_fabric_prices_the_hop_without_touching_tokens() {
    let mut runner = Runner::new("dist_sharded_fabric");
    runner.run(6, |g| {
        let w = gen_workload(g);
        let spec = ShardingSpec::new(Topology::nvlink(4));
        let mut loopback = Engine::new(
            engine_config(&w, PipelineConfig::default(), HashMap::new()),
            dist_backend(&w, 2, DistFabric::Loopback),
        );
        submit_all(&mut loopback, &w);
        let fp_loop = fingerprint(&mut loopback)?;
        let mut sharded = Engine::new(
            engine_config(&w, PipelineConfig::default(), HashMap::new()),
            dist_backend(&w, 2, DistFabric::Sharded(spec)),
        );
        submit_all(&mut sharded, &w);
        let fp_shard = fingerprint(&mut sharded)?;
        // Tokens and round structure are fabric-invariant…
        let tokens = |fp: &Fingerprint| {
            fp.completions
                .iter()
                .map(|(id, t, _, _)| (*id, t.clone()))
                .collect::<Vec<_>>()
        };
        ensure(
            tokens(&fp_loop) == tokens(&fp_shard),
            "sharded fabric changed tokens (it must only price communication)",
        )?;
        ensure(
            fp_loop.rounds == fp_shard.rounds,
            format!(
                "sharded fabric changed round count: {} vs {}",
                fp_loop.rounds, fp_shard.rounds
            ),
        )?;
        // …but the clock only moves forward (hop cost ≥ 0, and > 0 as
        // soon as at least one verify happened).
        ensure(
            fp_shard.clock >= fp_loop.clock,
            format!(
                "sharded clock {} behind loopback {}",
                fp_shard.clock, fp_loop.clock
            ),
        )?;
        if fp_loop.time_verify > 0.0 {
            ensure(
                fp_shard.clock > fp_loop.clock,
                "verify rounds ran but the fabric hop priced nothing",
            )?;
        }
        ensure(true, "")
    });
}

/// Pipelining must be a pure wall-clock optimisation: multiple in-flight
/// ops, out-of-order straggler completion, and overlapped admit/evict
/// acks change no computed value. Every (verify ranks, draft replicas)
/// cell of the grid must be bit-for-bit identical to the serial
/// (drain-after-every-op) coordinator, which PR 9 already pinned to the
/// single-process engine.
#[test]
fn prop_dist_pipelined_equals_serial_bit_for_bit() {
    let mut runner = Runner::new("dist_pipelined_vs_serial");
    runner.run(3, |g| {
        let w = gen_workload(g);
        for d in 1..=4usize {
            for dw in [1usize, 2] {
                let cfg = |pipeline: bool| DistConfig {
                    verify_ranks: d,
                    draft_ranks: dw,
                    pipeline,
                    ..Default::default()
                };
                let mut serial = Engine::new(
                    engine_config(&w, PipelineConfig::default(), HashMap::new()),
                    dist_backend_with(&w, cfg(false)),
                );
                submit_all(&mut serial, &w);
                let fp_serial = fingerprint(&mut serial)?;
                let mut piped = Engine::new(
                    engine_config(&w, PipelineConfig::default(), HashMap::new()),
                    dist_backend_with(&w, cfg(true)),
                );
                submit_all(&mut piped, &w);
                let fp_piped = fingerprint(&mut piped)?;
                if fp_serial != fp_piped {
                    return Err(diverged(
                        &format!("pipelined(d={d}, draft={dw}) vs serial"),
                        &fp_serial,
                        &fp_piped,
                    ));
                }
            }
        }
        ensure(true, "")
    });
}

/// Op-log compaction must be invisible to the computation. A window of 4
/// forces a snapshot+truncate cycle every couple of rounds; the run must
/// still be bit-for-bit the single-process run, the status counters must
/// show compaction actually fired, and the surviving log must stay
/// bounded by the window (plus the few ops logged since the last cut).
#[test]
fn dist_compaction_is_bit_invisible_and_bounds_the_log() {
    let w = Workload {
        alpha: 0.8,
        gamma: 3,
        max_batch: 4,
        blocks: 48,
        seed: 4242,
        specs: vec![(6, 20, 0.0), (4, 16, 0.01), (9, 24, 0.02), (5, 12, 0.03)],
    };
    let mut single = Engine::new(
        engine_config(&w, PipelineConfig::default(), HashMap::new()),
        synthetic(&w),
    );
    submit_all(&mut single, &w);
    let fp_single = fingerprint(&mut single).unwrap();

    let mut dist = Engine::new(
        engine_config(&w, PipelineConfig::default(), HashMap::new()),
        dist_backend_with(
            &w,
            DistConfig {
                verify_ranks: 2,
                oplog_window: 4,
                ..Default::default()
            },
        ),
    );
    submit_all(&mut dist, &w);
    let fp_dist = fingerprint(&mut dist).unwrap();
    assert_eq!(fp_single, fp_dist, "compaction changed computed state");

    let status = dist.backend().dist_status().unwrap();
    assert!(
        status.snapshots > 0,
        "window=4 never triggered a snapshot: {status:?}"
    );
    assert!(
        status.compacted_ops > 0,
        "snapshot retired no log entries: {status:?}"
    );
    // The log is checked against the window at every compute-op entry,
    // and at most one round's worth of ops (propose + verify + state-op
    // flushes) lands between checks.
    assert!(
        status.oplog_len <= 12,
        "op log unbounded despite window=4: {status:?}"
    );
    // With 2 verify ranks on a first-response quorum, every verify op
    // leaves a straggler to complete in flight.
    assert!(
        status.pipelined > 0,
        "no op completed in flight: {status:?}"
    );
}

/// Draft scale-out: striped propose across two draft replicas re-prices
/// the round (max over stripe costs; each stripe draws its own RNG
/// stream) so the clock may differ from single-process — but rejection
/// sampling is lossless at temperature 0, so the emitted tokens must
/// still be exactly the deterministic oracle chains.
#[test]
fn prop_dist_draft_scaleout_keeps_tokens_lossless() {
    let mut runner = Runner::new("dist_draft_scaleout");
    runner.run(6, |g| {
        let w = gen_workload(g);
        let d = g.usize_in(1, 2);
        let mut e = Engine::new(
            engine_config(&w, PipelineConfig::default(), HashMap::new()),
            dist_backend_with(
                &w,
                DistConfig {
                    verify_ranks: d,
                    draft_ranks: 2,
                    ..Default::default()
                },
            ),
        );
        submit_all(&mut e, &w);
        let fp = fingerprint(&mut e)?;
        ensure(
            fp.completions.len() == w.specs.len(),
            format!(
                "lost requests: {} of {} completed",
                fp.completions.len(),
                w.specs.len()
            ),
        )?;
        let reference = synthetic(&w);
        for (id, tokens, _, _) in &fp.completions {
            let (prompt_len, max_new, _) = w.specs[*id as usize];
            ensure(
                tokens.len() == max_new,
                format!("seq {id}: {} tokens != {max_new}", tokens.len()),
            )?;
            ensure(
                *tokens == reference.expected_chain(*id, prompt_len, max_new),
                format!("seq {id}: striped-draft tokens diverge from oracle chain"),
            )?;
        }
        let status = e.backend().dist_status().expect("dist status");
        ensure(
            status.workers.len() == 2 + d,
            format!("fleet is {} workers, want {}", status.workers.len(), 2 + d),
        )?;
        ensure(status.respawns == 0, "scale-out run recorded respawns")
    });
}

#[test]
fn dist_status_reports_the_fleet() {
    let w = Workload {
        alpha: 0.9,
        gamma: 3,
        max_batch: 4,
        blocks: 32,
        seed: 99,
        specs: vec![(5, 10, 0.0), (7, 8, 0.01)],
    };
    let mut e = Engine::new(
        engine_config(&w, PipelineConfig::default(), HashMap::new()),
        dist_backend(&w, 2, DistFabric::Loopback),
    );
    submit_all(&mut e, &w);
    e.run_to_completion(40_000).unwrap();
    let status = e.backend().dist_status().expect("dist backend has status");
    assert_eq!(status.workers.len(), 3, "1 draft + 2 verify ranks");
    assert!(status.workers.iter().all(|h| h.alive));
    assert_eq!(status.workers[0].rank, 0);
    assert!(
        status.workers.iter().all(|h| h.ops > 0),
        "every worker executed compute ops: {status:?}"
    );
    assert_eq!(status.respawns, 0);
    assert_eq!(status.retries, 0);
    // Single-process backends report no fleet.
    assert!(synthetic(&w).dist_status().is_none());
    // The JSON surface carries the health table (ServerStats embeds this
    // verbatim under the "dist" key).
    let json = status.to_json().to_string();
    for key in ["workers", "alive", "queue_depth", "respawns", "stale_discarded"] {
        assert!(json.contains(key), "status JSON missing {key}: {json}");
    }
}
