//! Integration over the experiments layer: cheap versions of the figure
//! pipelines (the full grids run in `cargo bench`).

use moesd::experiments::*;
use moesd::workload::Dataset;

#[test]
fn fig2_first_panel_shape() {
    let panel = &fig2::default_panels()[0];
    let stats = fig2::sweep_panel(panel, 1).unwrap();
    fig2::check_shape(&stats).unwrap();
}

#[test]
fn fig3_shape() {
    let out = fig3::run(3);
    fig3::check_shape(&out).unwrap();
}

#[test]
fn fig6_mtbench_t1_shape() {
    // The hardest panel (lowest α): MoE should still show the pattern.
    let out = fig6::run(Dataset::MtBench, 1.0, 3, 5).unwrap();
    fig6::check_shape(&out).unwrap();
}

#[test]
fn peak_speedup_helper() {
    let stats = vec![
        PairStats {
            batch: 1,
            gamma: 2,
            t_ar: 1.0,
            t_sd: 1.0,
            sigma: 0.9,
            speedup: 1.0,
            target_efficiency: 0.5,
        },
        PairStats {
            batch: 16,
            gamma: 2,
            t_ar: 2.0,
            t_sd: 1.0,
            sigma: 0.9,
            speedup: 2.0,
            target_efficiency: 0.9,
        },
    ];
    assert_eq!(peak_speedup(&stats).batch, 16);
}

#[test]
fn vocab_scale_full_sweep_at_realistic_vocab() {
    // The acceptance criterion for the sparse-logits tentpole: a full
    // fig2-style 19-point batch sweep at Qwen2's real 151936-entry vocab
    // completes under the parallel runner, and its speedups agree with
    // the toy-vocab sweep (the virtual clock is vocab-independent).
    let out = vocab_scale::run(&[64, 151_936], 4, 0.9, 21).unwrap();
    vocab_scale::check_shape(&out).unwrap();
    assert_eq!(out.speedups[1].len(), paper_batch_grid().len());
    // The realistic-vocab sweep shows the same headline result.
    let peak = out.speedups[1]
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(peak > 1.4, "SD should win at moderate batch: peak {peak}");
}

#[test]
fn table1_single_cell_sanity() {
    let row = tables::compute_row("2xGPU-A", "qwen2", Dataset::HumanEval, 0.0, 9).unwrap();
    // γ ordering on the most predictable workload.
    assert!(row.cells[0].speedup < row.cells[2].speedup);
    // The γ=4 σ calibration matches Table 1's 0.91.
    assert!((row.cells[2].sigma - 0.91).abs() < 0.08);
}
