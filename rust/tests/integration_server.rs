//! TCP front-end integration: spin up the server on an ephemeral port with
//! the synthetic backend, drive it with concurrent clients.

use moesd::batching::Buckets;
use moesd::engine::EngineConfig;
use moesd::hardware::platform_2x_gpu_a;
use moesd::kvcache::KvConfig;
use moesd::scheduler::SchedulerConfig;
use moesd::server::{Client, Server};
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;

fn tiny_platform_backend(seed: u64) -> SyntheticLm {
    // Use the tiny arch in the simulator so simulated times are micro-scale
    // and the test completes instantly on the virtual clock.
    let target = ExecSim::new(
        moesd::arch::presets::moesd_tiny(),
        platform_2x_gpu_a(),
    );
    let draft = ExecSim::new(
        moesd::arch::presets::moesd_tiny_draft(),
        platform_2x_gpu_a(),
    );
    SyntheticLm::new(target, draft, 0.9, seed)
}

fn config() -> EngineConfig {
    EngineConfig {
        gamma: 3,
        kv: KvConfig {
            num_blocks: 1024,
            block_size: 16,
        },
        scheduler: SchedulerConfig {
            max_batch: 16,
            admit_reserve_tokens: 64,
            tpot_slo: None,
        },
        buckets: Buckets::pow2_up_to(16),
        seed: 1,
        control: None,
        ..Default::default()
    }
}

#[test]
fn serve_one_request() {
    let server = Server::start("127.0.0.1:0", config(), tiny_platform_backend(5)).unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    let resp = client.generate("INFO GET /api", 16, 0.0).unwrap();
    // The synthetic chain may emit the EOS byte and stop early.
    let n = resp.get("n_tokens").unwrap().as_usize().unwrap();
    assert!((1..=16).contains(&n), "n_tokens={n}");
    assert!(resp.get("latency").unwrap().as_f64().unwrap() >= 0.0);
    assert!(resp.get("rounds").unwrap().as_usize().unwrap() >= 1);
    server.stop();
}

#[test]
fn serves_concurrent_clients_batched() {
    let server = Server::start("127.0.0.1:0", config(), tiny_platform_backend(6)).unwrap();
    let addr = server.addr;
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let resp = client
                    .generate(&format!("DEBUG expert[{i}] load="), 12, 0.0)
                    .unwrap();
                resp.get("n_tokens").unwrap().as_usize().unwrap()
            })
        })
        .collect();
    for h in handles {
        let n = h.join().unwrap();
        assert!((1..=12).contains(&n), "n_tokens={n}");
    }
    server.stop();
}

#[test]
fn sequential_requests_on_one_connection() {
    let server = Server::start("127.0.0.1:0", config(), tiny_platform_backend(7)).unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    for _ in 0..3 {
        let resp = client.generate("INFO worker=1 ", 8, 0.0).unwrap();
        let n = resp.get("n_tokens").unwrap().as_usize().unwrap();
        assert!((1..=8).contains(&n), "n_tokens={n}");
    }
    server.stop();
}

#[test]
fn stats_query_and_per_request_controller_state() {
    // Controller-enabled server: responses carry γ and controller
    // fields, and {"stats": true} returns the aggregate controller
    // snapshot (the adaptive control plane's observability surface).
    let target = ExecSim::new(moesd::arch::presets::moesd_tiny(), platform_2x_gpu_a());
    let draft = ExecSim::new(moesd::arch::presets::moesd_tiny_draft(), platform_2x_gpu_a());
    let mut cfg = config();
    cfg.control = Some(moesd::control::ControlConfig {
        alpha_prior: 0.9,
        ..moesd::control::ControlConfig::model_guided(
            moesd::control::CostModelSpec::roofline(target, draft),
        )
    });
    let server = Server::start("127.0.0.1:0", cfg, tiny_platform_backend(9)).unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    // Stats are served even before any generation (poll briefly: the
    // engine thread publishes its first snapshot asynchronously).
    let mut s0 = client.stats().unwrap();
    for _ in 0..200 {
        if s0.get("controller").is_some() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
        s0 = client.stats().unwrap();
    }
    assert!(s0.get("gamma").is_some(), "{s0}");
    assert!(s0.get("controller").is_some(), "{s0}");
    // A generation response carries per-request controller state.
    let resp = client.generate("INFO adaptive", 12, 0.0).unwrap();
    assert!(resp.get("gamma").unwrap().as_usize().is_some(), "{resp}");
    assert_eq!(
        resp.get("ctl_policy").unwrap().as_str().unwrap(),
        "model-guided"
    );
    // Aggregate stats moved after serving.
    let s1 = client.stats().unwrap();
    assert!(
        s1.get("tokens_generated").unwrap().as_usize().unwrap() > 0,
        "{s1}"
    );
    let ctl = s1.get("controller").unwrap();
    assert_eq!(ctl.get("policy").unwrap().as_str().unwrap(), "model-guided");
    assert!(ctl.get("intervals").is_some());
    server.stop();
}

#[test]
fn stats_without_controller_still_serve() {
    let server = Server::start("127.0.0.1:0", config(), tiny_platform_backend(10)).unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    let resp = client.generate("INFO plain", 8, 0.0).unwrap();
    // γ is reported (the static config value), controller fields absent.
    assert_eq!(resp.get("gamma").unwrap().as_usize().unwrap(), 3);
    assert!(resp.get("ctl_policy").is_none());
    let s = client.stats().unwrap();
    assert!(s.get("controller").is_none(), "{s}");
    assert_eq!(s.get("gamma").unwrap().as_usize().unwrap(), 3);
    server.stop();
}

#[test]
fn malformed_requests_get_error_responses() {
    let server = Server::start("127.0.0.1:0", config(), tiny_platform_backend(8)).unwrap();
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for bad in ["not json", "{\"no_prompt\": 1}", "{\"prompt\": \"\"}"] {
        stream.write_all(bad.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = moesd::util::json::Json::parse(&line).unwrap();
        assert!(resp.get("error").is_some(), "expected error for {bad}: {line}");
    }
    // The connection (and server) still works after errors.
    let mut client = Client::connect(server.addr).unwrap();
    assert!(client.generate("INFO ", 4, 0.0).is_ok());
    server.stop();
}

#[test]
fn multi_tenant_requests_and_per_class_stats() {
    use moesd::workload::parse_tenants;
    let mut cfg = config();
    cfg.tenants =
        parse_tenants("chat:prio=2,ttft=100.0,tpot=100.0,alpha=0.9;bulk:alpha=0.5").unwrap();
    cfg.admission = moesd::scheduler::AdmissionPolicyConfig::ClassAware(
        moesd::scheduler::ClassAwareConfig::default(),
    );
    let server = Server::start("127.0.0.1:0", cfg, tiny_platform_backend(9)).unwrap();
    let mut client = Client::connect(server.addr).unwrap();
    // Tagged requests echo their tenant and land in its stats bucket.
    let resp = client.generate_as("chat", "INFO tenant request", 8, 0.0).unwrap();
    assert_eq!(resp.get("tenant").unwrap().as_str().unwrap(), "chat");
    let resp = client.generate_as("bulk", "INFO other tenant", 8, 0.0).unwrap();
    assert_eq!(resp.get("tenant").unwrap().as_str().unwrap(), "bulk");
    // Untagged requests route to the lowest-priority class (never the
    // premium tier just because it is listed first).
    let resp = client.generate("INFO untagged", 8, 0.0).unwrap();
    assert_eq!(resp.get("tenant").unwrap().as_str().unwrap(), "bulk");
    // Unknown tenants are a client error, not silently class 0.
    let err = client.generate_as("nope", "INFO x", 4, 0.0);
    assert!(err.is_err(), "unknown tenant must be rejected");
    // Per-class stats: both classes show completions; the generous SLOs
    // on chat report full attainment.
    let s = client.stats().unwrap();
    let classes = s.req_arr("classes").unwrap();
    assert_eq!(classes.len(), 2);
    assert_eq!(classes[0].req_str("name").unwrap(), "chat");
    assert_eq!(classes[1].req_str("name").unwrap(), "bulk");
    assert!(classes[0].get("requests_completed").unwrap().as_usize().unwrap() >= 1);
    assert!(classes[1].get("requests_completed").unwrap().as_usize().unwrap() >= 2);
    assert_eq!(
        classes[0].get("ttft_slo_attainment").unwrap().as_f64().unwrap(),
        1.0
    );
    assert!(
        classes[1].get("ttft_slo_attainment").unwrap().as_f64().is_none(),
        "bulk has no SLO"
    );
    server.stop();
}
