//! Cross-module integration: engine + scheduler + KV + synthetic backend
//! under realistic workloads (arrival processes, mixed lengths, SLOs).

use moesd::arch::presets;
use moesd::batching::Buckets;
use moesd::engine::{Engine, EngineConfig};
use moesd::hardware::{platform_2x_gpu_a, platform_by_name};
use moesd::kvcache::KvConfig;
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::theory;
use moesd::workload::{calibrated_alpha, Dataset, WorkloadProfile};

fn engine_with(alpha: f64, gamma: usize, max_batch: usize, seed: u64) -> Engine<SyntheticLm> {
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    let backend = SyntheticLm::new(target, draft, alpha, seed);
    Engine::new(
        EngineConfig {
            gamma,
            kv: KvConfig {
                num_blocks: 1 << 15,
                block_size: 16,
            },
            scheduler: SchedulerConfig {
                max_batch,
                admit_reserve_tokens: 64,
                tpot_slo: None,
            },
            buckets: Buckets::pow2_up_to(max_batch),
            seed,
            control: None,
            ..Default::default()
        },
        backend,
    )
}

#[test]
fn open_loop_workload_completes_with_sane_slos() {
    // Poisson arrivals at a private-serving rate; all requests complete,
    // TTFT/TPOT are finite and ordered sensibly.
    let profile = WorkloadProfile {
        dataset: Dataset::MtBench,
        temperature: 0.0,
        max_new_tokens: 32,
        arrival_rate: Some(50.0),
    };
    let reqs = profile.generate(60, 0, 7);
    let mut engine = engine_with(0.8, 3, 16, 3);
    for r in reqs {
        engine.submit(r);
    }
    let done = engine.run_to_completion(50_000).unwrap();
    assert_eq!(done.len(), 60);
    for c in &done {
        assert!(c.first_token_at >= c.arrival);
        assert!(c.finished_at >= c.first_token_at);
        assert_eq!(c.tokens.len(), 32);
    }
    // Batching happened (mean decode batch above 1).
    assert!(engine.metrics.mean_batch() > 1.5);
    engine.kv().check_invariants().unwrap();
}

#[test]
fn speedup_peaks_at_moderate_batch_through_the_full_stack() {
    // The paper's core claim measured through the *entire* coordinator:
    // sweep max_batch, compare SD vs AR decode times.
    let alpha = calibrated_alpha("qwen2", Dataset::HumanEval, 0.0, 4);
    let mut speedups = Vec::new();
    let batches = [1usize, 8, 32, 512];
    for &b in &batches {
        let mut times = Vec::new();
        for gamma in [4usize, 0] {
            let mut engine = engine_with(alpha, gamma, b, 5);
            let profile = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 24);
            for mut r in profile.generate(b, 0, 11) {
                // Cap prompts so B=512 fits comfortably in the cache.
                r.prompt.truncate(64.min(r.prompt.len()).max(2));
                engine.submit(r);
            }
            engine.run_to_completion(200_000).unwrap();
            times.push(engine.metrics.decode_time());
        }
        speedups.push(times[1] / times[0]);
    }
    // Moderate (32) beats tiny (1) and huge (512).
    assert!(
        speedups[2] > speedups[0],
        "B=32 {} should beat B=1 {}",
        speedups[2],
        speedups[0]
    );
    assert!(
        speedups[2] > speedups[3],
        "B=32 {} should beat B=512 {}",
        speedups[2],
        speedups[3]
    );
    assert!(speedups[2] > 1.5, "peak speedup {}", speedups[2]);
}

#[test]
fn offload_platform_widens_sd_win() {
    // §3.4: CPU-offloaded experts make the system so memory-bound that SD
    // keeps winning even at large batch.
    let alpha = 0.85;
    let gamma = 4;
    let b = 256;
    let run = |offload: bool| -> f64 {
        let platform = if offload {
            platform_2x_gpu_a().with_offload(30e9)
        } else {
            platform_2x_gpu_a()
        };
        let mut times = Vec::new();
        for g in [gamma, 0] {
            let target = ExecSim::new(presets::qwen2_57b_a14b(), platform.clone());
            let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
            let backend = SyntheticLm::new(target, draft, alpha, 9);
            let mut engine = Engine::new(
                EngineConfig {
                    gamma: g,
                    kv: KvConfig {
                        num_blocks: 1 << 15,
                        block_size: 16,
                    },
                    scheduler: SchedulerConfig {
                        max_batch: b,
                        admit_reserve_tokens: 16,
                        tpot_slo: None,
                    },
                    ..Default::default()
                },
                backend,
            );
            let profile = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 12);
            for mut r in profile.generate(b, 0, 13) {
                r.prompt.truncate(16);
                engine.submit(r);
            }
            engine.run_to_completion(100_000).unwrap();
            times.push(engine.metrics.decode_time());
        }
        times[1] / times[0]
    };
    let normal = run(false);
    let offloaded = run(true);
    assert!(
        offloaded > normal,
        "offloading should improve SD speedup at B={b}: {offloaded} vs {normal}"
    );
    assert!(offloaded > 1.5, "offloaded speedup {offloaded}");
}

#[test]
fn different_platforms_reproduce_table2_ordering() {
    let alpha = calibrated_alpha("qwen2", Dataset::HumanEval, 0.0, 4);
    let run = |platform_name: &str| -> f64 {
        let platform = platform_by_name(platform_name).unwrap();
        let mut times = Vec::new();
        for g in [4usize, 0] {
            let target = ExecSim::new(presets::qwen2_57b_a14b(), platform.clone());
            let draft = ExecSim::new(
                presets::qwen2_0_5b(),
                moesd::hardware::Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw),
            );
            let backend = SyntheticLm::new(target, draft, alpha, 17);
            let mut engine = Engine::new(
                EngineConfig {
                    gamma: g,
                    scheduler: SchedulerConfig {
                        max_batch: 32,
                        admit_reserve_tokens: 32,
                        tpot_slo: None,
                    },
                    ..Default::default()
                },
                backend,
            );
            let profile = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 24);
            for mut r in profile.generate(32, 0, 19) {
                r.prompt.truncate(32);
                engine.submit(r);
            }
            engine.run_to_completion(100_000).unwrap();
            times.push(engine.metrics.decode_time());
        }
        times[1] / times[0]
    };
    let a = run("2xGPU-A");
    let b = run("2xGPU-B");
    assert!(b > a, "higher-ridge-point GPU-B should win: {b} vs {a}");
}

#[test]
fn sigma_invariant_to_batch_size() {
    // §4.1: "the acceptance rate across batch sizes merely fluctuates
    // within a small range" — acceptance is an algorithmic property.
    let alpha = 0.8;
    let gamma = 3;
    let mut sigmas = Vec::new();
    for &b in &[1usize, 8, 64] {
        let mut engine = engine_with(alpha, gamma, b, 23);
        // Long generations keep the per-point sampling error small (a
        // single 40-token sequence has ~±0.09 σ noise).
        let profile = WorkloadProfile::batch(Dataset::HumanEval, 0.0, 400);
        for mut r in profile.generate(b, 0, 29) {
            r.prompt.truncate(16);
            engine.submit(r);
        }
        engine.run_to_completion(100_000).unwrap();
        sigmas.push(engine.metrics.sigma(gamma));
    }
    let expect = theory::sigma_from_alpha(alpha, gamma);
    for (i, s) in sigmas.iter().enumerate() {
        assert!(
            (s - expect).abs() < 0.08,
            "σ at batch index {i}: {s} vs {expect}"
        );
    }
}

// ---------------------------------------------------------------------------
// Failure injection: a backend wrapper that errors on chosen verify calls.
// The engine must roll the round back and retry to a correct completion.
// ---------------------------------------------------------------------------

struct Flaky<B: moesd::spec::SdBackend> {
    inner: B,
    verify_calls: std::cell::Cell<u64>,
    fail_every: u64,
}

impl<B: moesd::spec::SdBackend> moesd::spec::SdBackend for Flaky<B> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }
    fn prefill(&mut self, batch: &[(u64, Vec<u32>)]) -> anyhow::Result<f64> {
        self.inner.prefill(batch)
    }
    fn propose(
        &mut self,
        seqs: &[u64],
        pending: &[Vec<u32>],
        gammas: &[usize],
        temps: &[f64],
        seed: u64,
    ) -> anyhow::Result<moesd::spec::ProposeOut> {
        self.inner.propose(seqs, pending, gammas, temps, seed)
    }
    fn verify(
        &mut self,
        seqs: &[u64],
        feed: &[u32],
        drafts: &[Vec<u32>],
        temps: &[f64],
    ) -> anyhow::Result<moesd::spec::VerifyOut> {
        let n = self.verify_calls.get() + 1;
        self.verify_calls.set(n);
        if n % self.fail_every == 0 {
            anyhow::bail!("injected verify failure #{n}");
        }
        self.inner.verify(seqs, feed, drafts, temps)
    }
    fn rollback_target(&mut self, seq: u64, len: usize) {
        self.inner.rollback_target(seq, len)
    }
    fn rollback_draft(&mut self, seq: u64, len: usize) {
        self.inner.rollback_draft(seq, len)
    }
    fn target_len(&self, seq: u64) -> usize {
        self.inner.target_len(seq)
    }
    fn draft_len(&self, seq: u64) -> usize {
        self.inner.draft_len(seq)
    }
    fn release(&mut self, seq: u64) {
        self.inner.release(seq)
    }
    fn reject_cost(&self, gammas: &[usize]) -> f64 {
        self.inner.reject_cost(gammas)
    }
}

#[test]
fn injected_failures_roll_back_and_retry_losslessly() {
    use moesd::batching::{Request, SamplingParams};
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    let inner = SyntheticLm::new(target, draft, 0.8, 31);
    let expected: Vec<Vec<u32>> = (0..4u64).map(|id| inner.expected_chain(id, 6, 20)).collect();
    let flaky = Flaky {
        inner,
        verify_calls: std::cell::Cell::new(0),
        fail_every: 3, // every third verify call explodes
    };
    let mut engine = Engine::new(
        EngineConfig {
            gamma: 3,
            ..Default::default()
        },
        flaky,
    );
    for id in 0..4u64 {
        engine.submit(Request {
            id,
            prompt: (0..6u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 20,
                eos_token: None,
            },
            arrival: 0.0,
            class: 0,
        });
    }
    // Drive manually, tolerating the injected errors.
    let mut done = Vec::new();
    let mut failures = 0;
    for _ in 0..10_000 {
        if engine.is_idle() {
            break;
        }
        match engine.step() {
            Ok(c) => done.extend(c),
            Err(e) => {
                assert!(format!("{e:#}").contains("injected"), "unexpected error: {e:#}");
                failures += 1;
            }
        }
    }
    assert!(failures >= 2, "injection should have fired (got {failures})");
    assert_eq!(engine.counters.get("round_failures"), failures);
    assert_eq!(done.len(), 4);
    done.sort_by_key(|c| c.id);
    for (c, want) in done.iter().zip(&expected) {
        assert_eq!(&c.tokens, want, "losslessness after retries (seq {})", c.id);
    }
    engine.kv().check_invariants().unwrap();
}

#[test]
fn tpot_slo_caps_batch_size() {
    use moesd::batching::{Request, SamplingParams};
    // Same workload, with and without a tight TPOT SLO: the SLO run must
    // keep the decode batch smaller and achieve a lower mean TPOT.
    let run = |slo: Option<f64>| -> (f64, f64) {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        let backend = SyntheticLm::new(target, draft, 0.85, 41);
        let mut engine = Engine::new(
            EngineConfig {
                gamma: 3,
                scheduler: SchedulerConfig {
                    max_batch: 64,
                    admit_reserve_tokens: 64,
                    tpot_slo: slo,
                },
                ..Default::default()
            },
            backend,
        );
        for id in 0..64u64 {
            engine.submit(Request {
                id,
                prompt: (0..8u32).collect(),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: 48,
                    eos_token: None,
                },
                arrival: 0.0,
                class: 0,
            });
        }
        let done = engine.run_to_completion(100_000).unwrap();
        assert_eq!(done.len(), 64);
        let mean_tpot =
            done.iter().map(|c| c.tpot()).sum::<f64>() / done.len() as f64;
        (engine.metrics.mean_batch(), mean_tpot)
    };
    let (batch_free, tpot_free) = run(None);
    // SLO chosen tighter than the free-running TPOT.
    let (batch_slo, tpot_slo) = run(Some(tpot_free * 0.6));
    assert!(
        batch_slo < batch_free,
        "SLO should shrink the batch: {batch_slo} vs {batch_free}"
    );
    assert!(
        tpot_slo < tpot_free,
        "SLO run should improve TPOT: {tpot_slo} vs {tpot_free}"
    );
}
