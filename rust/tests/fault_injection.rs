//! Fault-injection suite for the distributed engine (PR 9):
//!
//! [`FaultyTransport`] drops/delays frames on a deterministic counter
//! schedule and `die_after` kills workers mid-run; every scenario must
//! end with output bit-identical to the clean single-process engine —
//! faults may cost retries, respawns, and wall-clock, but never a token,
//! a virtual-clock tick, or a metric:
//!
//! * dropped **requests** → the coordinator times out and retransmits;
//! * dropped **responses** → the retransmit hits the worker's response
//!   cache (idempotent ops, never re-executed);
//! * delayed **responses** → the late copy and the retry's copy race,
//!   and whichever loses is discarded as a stale duplicate;
//! * worker **death** → respawn + op-log replay reconverges the replica;
//! * total blackout → a typed error within bounded time, never a hang.

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::dist::{DistBackend, DistConfig, FaultPlan, Role};
use moesd::engine::{Engine, EngineConfig, PipelineConfig};
use moesd::hardware::platform_2x_gpu_a;
use moesd::kvcache::KvConfig;
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::spec::SdBackend;
use moesd::testkit::{ensure, Gen, Runner};
use std::collections::HashMap;
use std::time::Duration;

struct Workload {
    alpha: f64,
    gamma: usize,
    max_batch: usize,
    blocks: usize,
    seed: u64,
    specs: Vec<(usize, usize, f64)>, // (prompt_len, max_new, arrival)
}

/// A fixed mid-size workload: enough rounds that every fault cadence
/// fires several times, small enough to keep the suite fast.
fn workload(seed: u64) -> Workload {
    Workload {
        alpha: 0.85,
        gamma: 3,
        max_batch: 4,
        blocks: 48,
        seed,
        specs: vec![(6, 14, 0.0), (4, 12, 0.01), (9, 16, 0.02), (3, 10, 0.03)],
    }
}

fn synthetic(w: &Workload) -> SyntheticLm {
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    SyntheticLm::new(target, draft, w.alpha, w.seed)
}

fn engine_config(w: &Workload) -> EngineConfig {
    EngineConfig {
        gamma: w.gamma,
        kv: KvConfig {
            num_blocks: w.blocks,
            block_size: 4,
        },
        scheduler: SchedulerConfig {
            max_batch: w.max_batch,
            admit_reserve_tokens: 4,
            tpot_slo: None,
        },
        seed: w.seed,
        pipeline: PipelineConfig::default(),
        gamma_overrides: HashMap::new(),
        ..Default::default()
    }
}

fn submit_all<B: SdBackend>(e: &mut Engine<B>, w: &Workload) {
    for (i, &(prompt_len, max_new, arrival)) in w.specs.iter().enumerate() {
        e.submit(Request {
            id: i as u64,
            prompt: (0..prompt_len as u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: max_new,
                eos_token: None,
            },
            arrival,
            class: 0,
        });
    }
}

/// Distributed backend with the given robustness/fault knobs. The
/// deadline is short: synthetic timeouts are immediate, and a dropped
/// request only costs one deadline before the retransmit.
fn faulty_backend(w: &Workload, ranks: usize, cfg_patch: DistConfig) -> DistBackend<SyntheticLm> {
    let (alpha, seed) = (w.alpha, w.seed);
    let factory = move || -> anyhow::Result<SyntheticLm> {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        Ok(SyntheticLm::new(target, draft, alpha, seed))
    };
    DistBackend::launch(
        DistConfig {
            verify_ranks: ranks,
            ..cfg_patch
        },
        factory,
    )
    .expect("dist launch")
}

#[derive(Debug, PartialEq)]
struct Fingerprint {
    completions: Vec<(u64, Vec<u32>, f64, f64)>,
    rounds: u64,
    clock: f64,
    time_draft: f64,
    time_verify: f64,
    time_reject: f64,
    time_prefill: f64,
}

fn fingerprint<B: SdBackend>(e: &mut Engine<B>) -> Result<Fingerprint, String> {
    let mut done = e
        .run_to_completion(40_000)
        .map_err(|err| format!("run failed: {err}"))?;
    done.sort_by_key(|c| c.id);
    Ok(Fingerprint {
        completions: done
            .into_iter()
            .map(|c| (c.id, c.tokens, c.ttft(), c.finished_at))
            .collect(),
        rounds: e.metrics.rounds,
        clock: e.clock(),
        time_draft: e.metrics.time_draft,
        time_verify: e.metrics.time_verify,
        time_reject: e.metrics.time_reject,
        time_prefill: e.metrics.time_prefill,
    })
}

fn clean_fingerprint(w: &Workload) -> Fingerprint {
    let mut e = Engine::new(engine_config(w), synthetic(w));
    submit_all(&mut e, w);
    fingerprint(&mut e).expect("clean run")
}

/// Run the workload through a faulted distributed engine and require
/// bit-exact parity with the clean single-process run, plus whatever
/// robustness counters the scenario must have exercised. Returns the
/// end-of-run `DistStatus` for scenario-specific assertions.
fn check_faulted_parity(
    w: &Workload,
    ranks: usize,
    cfg: DistConfig,
    what: &str,
) -> Result<moesd::dist::DistStatus, String> {
    let clean = clean_fingerprint(w);
    let mut e = Engine::new(engine_config(w), faulty_backend(w, ranks, cfg));
    submit_all(&mut e, w);
    let faulted = fingerprint(&mut e)?;
    if clean != faulted {
        return Err(format!(
            "{what}: faulted run diverged\n  clean:   rounds {} clock {}\n  faulted: rounds {} clock {}",
            clean.rounds, clean.clock, faulted.rounds, faulted.clock
        ));
    }
    // Lossless against the oracle, not merely self-consistent.
    let reference = synthetic(w);
    for (id, tokens, _, _) in &faulted.completions {
        let (prompt_len, max_new, _) = w.specs[*id as usize];
        if *tokens != reference.expected_chain(*id, prompt_len, max_new) {
            return Err(format!("{what}: seq {id} tokens diverge from oracle chain"));
        }
    }
    Ok(e.backend().dist_status().expect("dist status"))
}

fn fault_cfg(plan: FaultPlan) -> DistConfig {
    DistConfig {
        deadline: Duration::from_millis(40),
        faults: Some(plan),
        ..DistConfig::default()
    }
}

#[test]
fn dropped_requests_are_retransmitted_losslessly() {
    let status = check_faulted_parity(
        &workload(7001),
        1,
        fault_cfg(FaultPlan {
            drop_req_every: Some(5),
            ..FaultPlan::default()
        }),
        "drop_req_every=5",
    )
    .unwrap();
    assert!(status.retries > 0, "no retries recorded: {status:?}");
    assert_eq!(status.respawns, 0, "drops must not escalate to respawns");
}

#[test]
fn dropped_responses_hit_the_idempotent_response_cache() {
    // The worker executed the op and cached the response; the retry must
    // replay the cache, not re-execute (re-execution would corrupt
    // non-idempotent compute state and break parity).
    let status = check_faulted_parity(
        &workload(7002),
        2,
        fault_cfg(FaultPlan {
            drop_resp_every: Some(6),
            ..FaultPlan::default()
        }),
        "drop_resp_every=6",
    )
    .unwrap();
    assert!(status.retries > 0, "no retries recorded: {status:?}");
}

#[test]
fn delayed_responses_are_discarded_as_stale_duplicates() {
    // The held original and the retry's copy race; exactly one is
    // consumed and the loser must be discarded by op-id/slot matching.
    let status = check_faulted_parity(
        &workload(7003),
        2,
        fault_cfg(FaultPlan {
            delay_resp_every: Some(5),
            ..FaultPlan::default()
        }),
        "delay_resp_every=5",
    )
    .unwrap();
    assert!(status.retries > 0, "no retries recorded: {status:?}");
    assert!(
        status.stale_discarded > 0,
        "no stale duplicates discarded: {status:?}"
    );
}

#[test]
fn draft_worker_death_respawns_and_replays_losslessly() {
    let status = check_faulted_parity(
        &workload(7004),
        1,
        DistConfig {
            deadline: Duration::from_millis(500),
            die_after: vec![(Role::Draft, 0, 5)],
            ..DistConfig::default()
        },
        "draft dies after 5 ops",
    )
    .unwrap();
    assert!(status.respawns >= 1, "no respawn recorded: {status:?}");
    assert!(
        status.workers.iter().all(|h| h.alive),
        "fleet not fully alive after recovery: {status:?}"
    );
    assert!(status.workers[0].respawns >= 1, "draft slot not respawned");
}

#[test]
fn verify_rank_death_respawns_and_replays_losslessly() {
    let status = check_faulted_parity(
        &workload(7005),
        2,
        DistConfig {
            deadline: Duration::from_millis(500),
            die_after: vec![(Role::Verify, 1, 4)],
            ..DistConfig::default()
        },
        "verify rank 1 dies after 4 ops",
    )
    .unwrap();
    assert!(status.respawns >= 1, "no respawn recorded: {status:?}");
    assert!(status.workers.iter().all(|h| h.alive));
    // Slot 2 is verify rank 1.
    assert!(status.workers[2].respawns >= 1, "rank-1 slot not respawned");
}

/// PR 10: death mid-pipeline with a tiny op-log window. By the time the
/// worker dies the log has been compacted several times, so the respawn
/// must rebuild the replica from the snapshot plus the O(window) log
/// tail — not from the full op history — and still land bit-exact with
/// zero token loss (check_faulted_parity pins both).
#[test]
fn death_mid_pipeline_replays_from_snapshot_not_history() {
    let status = check_faulted_parity(
        &workload(7010),
        2,
        DistConfig {
            deadline: Duration::from_millis(500),
            oplog_window: 4,
            die_after: vec![(Role::Verify, 0, 17)],
            ..DistConfig::default()
        },
        "verify rank 0 dies mid-pipeline, window=4",
    )
    .unwrap();
    assert!(status.respawns >= 1, "no respawn recorded: {status:?}");
    assert!(
        status.snapshots >= 1,
        "window=4 never snapshotted before the death: {status:?}"
    );
    // Bounded replay: per respawn, at most the snapshot (one synthesized
    // prefill chunk per 256 live seqs + the draft-side clamp) plus the
    // window and the few ops logged since the last cut — far below the
    // 17+ ops the dead worker had executed.
    assert!(
        status.replayed_ops <= status.respawns * 16,
        "replay was not O(window): {status:?}"
    );
    assert!(status.workers.iter().all(|h| h.alive));
}

/// Same ladder with draft replicas striped: the dying worker is one of
/// two draft ranks, so its replay path exercises the per-rank stripe
/// frames kept in the compacted log.
#[test]
fn striped_draft_death_respawns_losslessly() {
    let w = workload(7011);
    let clean = clean_fingerprint(&w);
    let mut e = Engine::new(
        engine_config(&w),
        faulty_backend(
            &w,
            1,
            DistConfig {
                deadline: Duration::from_millis(500),
                draft_ranks: 2,
                oplog_window: 6,
                die_after: vec![(Role::Draft, 1, 5)],
                ..DistConfig::default()
            },
        ),
    );
    submit_all(&mut e, &w);
    let faulted = fingerprint(&mut e).unwrap();
    // Striped drafting re-prices the clock, so only the tokens are
    // comparable against the clean run — and they must match exactly.
    let tokens = |fp: &Fingerprint| {
        fp.completions
            .iter()
            .map(|(id, t, _, _)| (*id, t.clone()))
            .collect::<Vec<_>>()
    };
    assert_eq!(
        tokens(&clean),
        tokens(&faulted),
        "striped-draft death lost or corrupted tokens"
    );
    let status = e.backend().dist_status().unwrap();
    assert!(status.respawns >= 1, "no respawn recorded: {status:?}");
    // Slot 1 is draft rank 1.
    assert!(status.workers[1].respawns >= 1, "rank-1 draft not respawned");
    assert!(status.workers.iter().all(|h| h.alive));
}

#[test]
fn combined_chaos_still_bit_exact() {
    // Everything at once: dropped requests, delayed responses, and a
    // mid-run draft-worker crash. Output must still be bit-exact.
    let status = check_faulted_parity(
        &workload(7006),
        2,
        DistConfig {
            deadline: Duration::from_millis(60),
            faults: Some(FaultPlan {
                drop_req_every: Some(9),
                delay_resp_every: Some(7),
                ..FaultPlan::default()
            }),
            die_after: vec![(Role::Draft, 0, 6)],
            ..DistConfig::default()
        },
        "chaos (drop+delay+death)",
    )
    .unwrap();
    assert!(status.retries > 0, "chaos run recorded no retries: {status:?}");
    assert!(status.respawns >= 1, "chaos run recorded no respawn: {status:?}");
}

#[test]
fn prop_random_fault_cadence_never_loses_tokens() {
    // Parity must hold for *any* fault cadence, not just the pinned
    // ones. Cases stay few because each dropped request costs one
    // deadline of wall-clock.
    let mut runner = Runner::new("fault_cadence_parity");
    runner.run(5, |g| {
        let w = workload(g.u64_in(0, 1 << 20));
        let plan = FaultPlan {
            drop_req_every: Some(g.u64_in(4, 9)),
            drop_resp_every: Some(g.u64_in(5, 11)),
            delay_resp_every: Some(g.u64_in(6, 13)),
        };
        let status = check_faulted_parity(
            &w,
            g.usize_in(1, 2),
            fault_cfg(plan.clone()),
            &format!("random cadence {plan:?}"),
        )?;
        ensure(
            status.retries > 0,
            format!("cadence {plan:?} exercised nothing"),
        )
    });
}

#[test]
fn total_blackout_fails_bounded_not_hung() {
    // Every compute request dropped forever: retries and the one
    // wedged-worker respawn must exhaust within bounded time and surface
    // a typed error — never a hang, never a panic.
    let w = workload(7007);
    let start = std::time::Instant::now();
    let mut e = Engine::new(
        engine_config(&w),
        faulty_backend(
            &w,
            1,
            DistConfig {
                deadline: Duration::from_millis(20),
                max_retries: 1,
                faults: Some(FaultPlan {
                    drop_req_every: Some(1),
                    ..FaultPlan::default()
                }),
                ..DistConfig::default()
            },
        ),
    );
    submit_all(&mut e, &w);
    let err = e.run_to_completion(40_000).expect_err("blackout must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("dist:"), "untyped blackout error: {msg}");
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "blackout took {:?} — the failure ladder is unbounded",
        start.elapsed()
    );
}

#[test]
fn heartbeats_drive_the_health_table() {
    let w = workload(7008);
    let mut backend = faulty_backend(&w, 2, DistConfig::default());
    backend.ping().expect("ping");
    let status = backend.dist_status().unwrap();
    assert_eq!(status.workers.len(), 3);
    assert!(
        status.workers.iter().all(|h| h.heartbeat > 0),
        "heartbeat nonces not recorded: {status:?}"
    );
}
