//! End-to-end multi-tenant SLO-class serving claims (ISSUE 5 acceptance
//! criteria), on a shortened production-shaped trace:
//!
//! - class-aware admission meets strictly more SLO targets than FIFO at
//!   overload, while single-class deployments stay bit-compatible
//!   (covered in `prop_scheduler.rs`);
//! - mix-aware admission sustains the α-blind arm's measured speedup at
//!   every load and clears it at the top load (the served-mix α lever).

use moesd::experiments::multitenant;
use moesd::workload::ArrivalTrace;

fn sweep() -> multitenant::MultitenantOut {
    // The exact bench-default trace: the shape claims need the full-length
    // windows (shorter traces don't build enough backlog at the top load
    // for the FIFO failure or the composition skew to separate — measured
    // in the python replica during design).
    let trace = ArrivalTrace::synthetic_production(
        multitenant::TRACE_DURATION_S,
        multitenant::TRACE_BASE_RATE,
        42,
    );
    multitenant::run(&trace, &multitenant::default_loads(), 42).expect("sweep runs")
}

#[test]
fn multitenant_sweep_meets_acceptance_criteria() {
    let out = sweep();
    if let Err(e) = multitenant::check_shape(&out) {
        panic!("shape check failed: {e}");
    }
    let top = out.top_load();
    // Spot-check the mechanisms behind the shape claims.
    let fifo = out.arm(top, "fifo").unwrap();
    let class = out.arm(top, "class").unwrap();
    let mix = out.arm(top, "class+mix").unwrap();
    // Chat TTFT: hopeless behind FIFO's backlog, held by priority.
    assert!(
        fifo.classes[0].ttft_attainment.unwrap_or(1.0) < 0.9,
        "fifo should drop the chat TTFT SLO at overload: {:?}",
        fifo.classes[0].ttft_attainment
    );
    assert!(
        class.classes[0].ttft_attainment.unwrap_or(0.0) >= 0.9,
        "class-aware should hold it: {:?}",
        class.classes[0].ttft_attainment
    );
    // The mix arm's served composition leans on the easy bulk class
    // (higher served-mix α), which is where its goodput edge comes from.
    let served_easy = |arm: &multitenant::ArmStat| {
        let code = arm.classes[1].tokens as f64;
        let open = arm.classes[2].tokens as f64;
        code / (code + open).max(1.0)
    };
    assert!(
        served_easy(mix) > served_easy(class),
        "mix-aware should serve an easier bulk mix at overload: {:.3} vs {:.3}",
        served_easy(mix),
        served_easy(class)
    );
    // Work conservation: every arm completed a meaningful share of the
    // offered window load.
    for r in &out.rows {
        assert!(
            r.requests_completed as usize >= r.requests_offered / 20,
            "{}@{} completed too little: {}/{}",
            r.policy,
            r.load,
            r.requests_completed,
            r.requests_offered
        );
    }
}

#[test]
fn light_load_arms_are_equivalent() {
    // With no sustained backlog there is little to steer: the class-aware
    // arms hold the chat SLO and their goodputs stay near-identical.
    let trace = ArrivalTrace::synthetic_production(12.0, multitenant::TRACE_BASE_RATE, 42);
    let out = multitenant::run(&trace, &[0.5], 42).expect("sweep runs");
    let class = out.arm(0.5, "class").unwrap();
    let mix = out.arm(0.5, "class+mix").unwrap();
    for arm in [class, mix] {
        assert!(
            arm.classes[0].ttft_attainment.unwrap_or(0.0) >= 0.9,
            "{}: light load must hold the chat TTFT SLO: {:?}",
            arm.policy,
            arm.classes[0].ttft_attainment
        );
    }
    let rel = (mix.tok_s - class.tok_s).abs() / class.tok_s.max(1e-9);
    assert!(
        rel < 0.1,
        "light-load goodput should be near-identical: {} vs {}",
        mix.tok_s,
        class.tok_s
    );
}
