//! End-to-end tests of expert-budgeted verification (the (γ, budget)
//! axis): the whole-engine budget off-switch, the replica-validated
//! joint-beats-decoupled claim at a memory-bound point, and the
//! adaptive-under-continuous observation-plumbing regression the PR-7
//! pipeline needs.

use moesd::arch::presets;
use moesd::batching::{Buckets, Request, SamplingParams};
use moesd::control::{ControlConfig, CostModelSpec};
use moesd::engine::{Engine, EngineConfig, PipelineConfig};
use moesd::experiments::budget;
use moesd::hardware::{platform_2x_gpu_a, Platform};
use moesd::kvcache::KvConfig;
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::spec::SdBackend;

/// The replica's memory-bound sweet spot: B = 16, α = 0.9, K = 8
/// (python/replica_budget.py puts the best budgeted arm 1.196× over the
/// best unbudgeted arm there at sensitivity 0.25).
const BATCH: usize = 16;
const ALPHA: f64 = 0.9;
const SENSITIVITY: f64 = 0.25;
const MAX_NEW: usize = 48;
const PROMPT: usize = 16;

fn sims() -> (ExecSim, ExecSim) {
    let platform = platform_2x_gpu_a();
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform.clone());
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = ExecSim::new(presets::qwen2_0_5b(), draft_platform);
    (target, draft)
}

fn req(id: u64, arrival: f64) -> Request {
    Request {
        id,
        prompt: (0..PROMPT as u32).collect(),
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: MAX_NEW,
            eos_token: None,
        },
        arrival,
        class: 0,
    }
}

/// Saturated steady-state goodput (committed tokens per second of
/// decode clock) over a fixed round window with immediate slot
/// replacement — the `experiments::budget` methodology, two trials.
fn steady_goodput(
    control: Option<ControlConfig>,
    curve: bool,
    static_budget: Option<usize>,
    window: usize,
    seed: u64,
) -> (u64, f64) {
    let mut tokens = 0u64;
    let mut decode = 0.0f64;
    for trial in 0..2u64 {
        let (tsim, dsim) = sims();
        let mut backend = SyntheticLm::new(tsim, dsim, ALPHA, seed.wrapping_add(trial));
        if curve {
            backend = backend.with_budget_alpha_curve(SENSITIVITY);
        }
        backend.set_verify_budget(static_budget);
        let config = EngineConfig {
            gamma: 0,
            control: control.clone(),
            kv: KvConfig {
                num_blocks: 1 << 14,
                block_size: 16,
            },
            scheduler: SchedulerConfig {
                max_batch: BATCH,
                admit_reserve_tokens: MAX_NEW,
                tpot_slo: None,
            },
            buckets: Buckets::pow2_up_to(BATCH),
            seed: seed.wrapping_add(trial),
            ..Default::default()
        };
        let mut engine = Engine::new(config, backend);
        let mut next_id: u64 = BATCH as u64;
        for id in 0..BATCH as u64 {
            engine.submit(req(id, 0.0));
        }
        for _ in 0..window {
            let completions = engine.step().unwrap();
            for _ in completions {
                engine.submit(req(next_id, engine.clock()));
                next_id += 1;
            }
        }
        tokens += engine.metrics.tokens_generated;
        decode += engine.metrics.decode_time();
    }
    assert!(decode > 0.0, "arm measured no decode time");
    (tokens, decode)
}

fn adaptive(budget_grid: Vec<usize>) -> ControlConfig {
    let (tsim, dsim) = sims();
    ControlConfig {
        budget_grid,
        budget_sensitivity: SENSITIVITY,
        ..ControlConfig::model_guided(CostModelSpec::roofline(tsim, dsim))
    }
}

/// Satellite 1 at whole-engine grain: with the controller's budget grid
/// empty the adaptive engine is bit-identical to PR-7 — carrying the
/// (inert) degradation curve, or a static whole-pool budget, changes
/// nothing: same tokens, same decode clock.
#[test]
fn empty_budget_grid_is_bit_identical_to_unbudgeted_adaptive() {
    let window = 60;
    let baseline = steady_goodput(Some(adaptive(vec![])), false, None, window, 77);
    let with_curve = steady_goodput(Some(adaptive(vec![])), true, None, window, 77);
    let whole_pool = steady_goodput(Some(adaptive(vec![])), true, Some(64), window, 77);
    assert_eq!(
        baseline, with_curve,
        "inert degradation curve perturbed the adaptive engine"
    );
    assert_eq!(
        baseline, whole_pool,
        "whole-pool static budget (= E) perturbed the adaptive engine"
    );
}

/// The acceptance criterion: at the replica-pinned memory-bound point
/// the joint (γ, budget) controller strictly beats the γ-only decoupled
/// controller (same model, same curve, budget grid off) by ≥ 2%. The
/// expected-value replica puts the static-arm edge at 1.196× here; the
/// pinned margin leaves headroom for adaptive-transient and sampling
/// noise.
#[test]
fn joint_gamma_budget_beats_decoupled_at_memory_bound_point() {
    let window = 150;
    let (dec_tok, dec_s) = steady_goodput(Some(adaptive(vec![])), true, None, window, 5);
    let (joint_tok, joint_s) =
        steady_goodput(Some(adaptive(vec![8, 16, 32, 48])), true, None, window, 5);
    let decoupled = dec_tok as f64 / dec_s;
    let joint = joint_tok as f64 / joint_s;
    assert!(
        joint >= 1.02 * decoupled,
        "joint (γ, budget) should beat γ-only at B={BATCH}: {joint:.1} vs {decoupled:.1} tok/s \
         (ratio {:.3}, replica predicts 1.196)",
        joint / decoupled
    );
}

/// The joint controller actually engages the budget axis (the win above
/// is not vacuous), and the engine keeps the backend in sync with the
/// controller's decision.
#[test]
fn joint_controller_engages_and_syncs_the_budget() {
    let (tsim, dsim) = sims();
    let backend = SyntheticLm::new(tsim, dsim, ALPHA, 11).with_budget_alpha_curve(SENSITIVITY);
    let config = EngineConfig {
        gamma: 0,
        control: Some(adaptive(vec![8, 16, 32, 48])),
        scheduler: SchedulerConfig {
            max_batch: BATCH,
            admit_reserve_tokens: MAX_NEW,
            tpot_slo: None,
        },
        buckets: Buckets::pow2_up_to(BATCH),
        seed: 11,
        ..Default::default()
    };
    let mut engine = Engine::new(config, backend);
    for id in 0..BATCH as u64 {
        engine.submit(req(id, 0.0));
    }
    for _ in 0..40 {
        if engine.is_idle() {
            break;
        }
        engine.step().unwrap();
    }
    let ctl = engine.controller().expect("controller present");
    assert!(ctl.owns_budget(), "non-empty grid must own the budget axis");
    let chosen = ctl.verify_budget();
    assert!(
        chosen.is_some(),
        "memory-bound point should pick a sub-coverage budget (got None)"
    );
    assert_eq!(
        engine.verify_budget(),
        chosen,
        "backend budget out of sync with the controller decision"
    );
    let st = engine.controller_state().expect("controller state");
    assert_eq!(st.budget, chosen);
    // Budgeted rounds landed in the budgeted acceptance arm, not the
    // unbudgeted baseline column (off-switch table purity).
    assert!(
        st.accept_by_budget.iter().any(|(b, _)| b.is_some()),
        "no budgeted acceptance samples recorded: {:?}",
        st.accept_by_budget
    );
}

/// Satellite 3: the continuous-batching pipeline feeds the controller
/// well-formed observations — non-empty acceptance samples on both
/// budget arms it ran, a monotone round clock (enforced by a
/// debug_assert inside `SpecController::observe`, live in test builds),
/// and a complete cost table — while staying lossless.
#[test]
fn adaptive_budget_under_continuous_pipeline_observes_well_formed_rounds() {
    let (tsim, dsim) = sims();
    let backend = SyntheticLm::new(tsim, dsim, ALPHA, 19).with_budget_alpha_curve(SENSITIVITY);
    let config = EngineConfig {
        gamma: 0,
        control: Some(adaptive(vec![8, 16, 32, 48])),
        pipeline: PipelineConfig {
            continuous: true,
            prefill_chunk: Some(64),
            draft_ahead: true,
            per_seq_boundaries: true,
        },
        scheduler: SchedulerConfig {
            max_batch: 8,
            admit_reserve_tokens: MAX_NEW,
            tpot_slo: None,
        },
        buckets: Buckets::pow2_up_to(8),
        seed: 19,
        ..Default::default()
    };
    let mut engine = Engine::new(config, backend);
    let n_reqs = 12u64;
    for id in 0..n_reqs {
        engine.submit(req(id, 0.002 * id as f64));
    }
    let done = engine.run_to_completion(50_000).unwrap();
    assert_eq!(done.len(), n_reqs as usize);
    for c in &done {
        assert_eq!(
            c.tokens,
            engine.backend().expected_chain(c.id, PROMPT, MAX_NEW),
            "seq {} lost losslessness under budgeted continuous rounds",
            c.id
        );
    }
    let ctl = engine.controller().expect("controller present");
    let st = engine.controller_state().expect("controller state");
    assert!(st.intervals > 0, "no control intervals closed: {st:?}");
    assert!(
        st.alpha_hat.is_some(),
        "no α̂ learned — observations missing acceptance signal: {st:?}"
    );
    // The acceptance-vs-budget curve has samples for every arm that ran,
    // and at minimum *some* arm ran (γ > 0 rounds with proposals).
    assert!(
        !st.accept_by_budget.is_empty(),
        "acceptance curve empty — RoundObservations malformed: {st:?}"
    );
    for (arm, rate) in &st.accept_by_budget {
        assert!(
            (0.0..=1.0).contains(rate),
            "acceptance ratio out of range on arm {arm:?}: {rate}"
        );
    }
    // The cost table saw real stage costs (verify entries from the
    // continuous verify ops).
    assert!(
        ctl.costs().busiest_verify().is_some(),
        "no verify costs observed through the continuous pipeline"
    );
}

/// The smoke grid of `moesd bench budget` — the CI gate — runs clean
/// end-to-end through the library entry point, including the exact
/// off-switch identity at every point.
#[test]
fn bench_budget_smoke_gate() {
    let out = budget::run(true, 1234).unwrap();
    budget::check_shape(&out).unwrap();
}
