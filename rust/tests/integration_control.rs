//! End-to-end tests of the adaptive speculation control plane: the
//! full traffic-ramp comparison, the compute-bound γ=0 fallback through
//! the real engine, and controller-driven SLO batch ceilings.

use moesd::arch::presets;
use moesd::batching::{Buckets, Request, SamplingParams};
use moesd::control::{ControlConfig, CostModelSpec, PolicyKind};
use moesd::engine::{Engine, EngineConfig};
use moesd::hardware::{platform_2x_gpu_a, Platform};
use moesd::kvcache::KvConfig;
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;

fn sims() -> (ExecSim, ExecSim) {
    let platform = platform_2x_gpu_a();
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform.clone());
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let draft = ExecSim::new(presets::qwen2_0_5b(), draft_platform);
    (target, draft)
}

fn engine(
    alpha: f64,
    max_batch: usize,
    control: Option<ControlConfig>,
    seed: u64,
) -> Engine<SyntheticLm> {
    let (tsim, dsim) = sims();
    let backend = SyntheticLm::new(tsim, dsim, alpha, seed);
    Engine::new(
        EngineConfig {
            gamma: 3,
            kv: KvConfig {
                num_blocks: 1 << 16,
                block_size: 16,
            },
            scheduler: SchedulerConfig {
                max_batch,
                admit_reserve_tokens: 32,
                tpot_slo: None,
            },
            buckets: Buckets::pow2_up_to(max_batch.max(1)),
            seed,
            control,
            ..Default::default()
        },
        backend,
    )
}

fn req(id: u64, max_new: usize, arrival: f64) -> Request {
    Request {
        id,
        prompt: (0..16u32).collect(),
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: max_new,
            eos_token: None,
        },
        arrival,
        class: 0,
    }
}

fn adaptive(alpha: f64) -> ControlConfig {
    let (tsim, dsim) = sims();
    ControlConfig {
        alpha_prior: alpha,
        ..ControlConfig::model_guided(CostModelSpec::roofline(tsim, dsim))
    }
}

#[test]
fn traffic_ramp_adaptive_tracks_best_static() {
    // The PR's acceptance criterion, end-to-end: ≥ 0.95× the best static
    // γ and strictly above the worst, in every ramp phase, with the γ=0
    // fallback engaged during the compute-bound phase.
    let out = moesd::experiments::adaptive::run(0.85, 42).unwrap();
    if let Err(e) = moesd::experiments::adaptive::check_shape(&out) {
        panic!("adaptive ramp shape violated: {e}");
    }
}

#[test]
fn compute_bound_batch_drives_gamma_to_zero_in_engine() {
    // Satellite requirement: γ=0 fallback when target efficiency
    // collapses at large B, through the real engine (not just the
    // policy unit test).
    let b = 512;
    let mut e = engine(0.85, b, Some(adaptive(0.85)), 3);
    for id in 0..b as u64 {
        e.submit(req(id, 32, 0.0));
    }
    let mut ar_rounds = 0u64;
    let mut rounds = 0u64;
    while !e.is_idle() {
        e.step().unwrap();
        rounds += 1;
        if e.current_gamma() == 0 && e.num_running() * 2 >= b {
            ar_rounds += 1;
        }
        assert!(rounds < 100_000, "engine did not drain");
    }
    assert!(
        ar_rounds * 2 > rounds / 2,
        "compute-bound bulk should mostly run AR: {ar_rounds}/{rounds} rounds"
    );
    let st = e.controller_state().unwrap();
    assert!(st.intervals > 0);
    assert!(st.switches >= 1, "controller never switched: {st:?}");
}

#[test]
fn memory_bound_batch_keeps_speculation_on() {
    // 32 requests through a batch-4 engine: enough sequence-rounds for
    // several control intervals, so α̂ actually converges.
    let mut e = engine(0.9, 4, Some(adaptive(0.9)), 5);
    for id in 0..32u64 {
        e.submit(req(id, 48, 0.0));
    }
    e.run_to_completion(10_000).unwrap();
    let st = e.controller_state().unwrap();
    assert!(st.gamma >= 1, "small-batch regime should speculate: {st:?}");
    assert!(
        e.metrics.draft_tokens_proposed > 0,
        "no speculative rounds ran"
    );
    // The online α̂ tracked the true acceptance probability.
    let a = st.alpha_hat.expect("alpha estimated");
    assert!((a - 0.9).abs() < 0.08, "α̂={a}");
}

#[test]
fn traffic_ramp_soak_open_loop_poisson_arrivals() {
    // Open-loop soak: a piecewise-Poisson TrafficRamp (4 → 32 → 256
    // req/s) floods the adaptive engine. Everything must complete, the
    // concurrency must actually ramp, and the controller must have
    // re-seated γ along the way.
    use moesd::workload::{Dataset, TrafficRamp, WorkloadProfile};
    let ramp = TrafficRamp::geometric(4.0, 8.0, 3, 4.0);
    let profile = WorkloadProfile {
        dataset: Dataset::HumanEval,
        temperature: 0.0,
        max_new_tokens: 16,
        arrival_rate: None, // the ramp owns arrivals
    };
    let mut requests = ramp.generate(&profile, 0, 21);
    for r in &mut requests {
        r.prompt.truncate(24); // keep prefill cheap at B≈256
    }
    let n = requests.len();
    assert!(n > 500, "ramp should generate a real load: {n} requests");

    let mut e = engine(0.85, 256, Some(adaptive(0.85)), 13);
    for r in requests {
        e.submit(r);
    }
    let mut peak_running = 0;
    let mut steps = 0u64;
    while !e.is_idle() {
        e.step().unwrap();
        peak_running = peak_running.max(e.num_running());
        steps += 1;
        assert!(steps < 500_000, "soak did not drain");
    }
    assert_eq!(e.metrics.requests_completed as usize, n);
    assert!(
        peak_running >= 32,
        "high-rate phase should batch up: peak={peak_running}"
    );
    let st = e.controller_state().unwrap();
    assert!(
        st.switches >= 1,
        "controller should adapt across the ramp: {st:?}"
    );
    assert!(st.alpha_hat.is_some());
    e.kv().check_invariants().unwrap();
}

#[test]
fn static_policy_controller_reports_but_does_not_steer() {
    let mut e = engine(0.8, 8, Some(ControlConfig::static_gamma(2)), 9);
    for id in 0..8u64 {
        e.submit(req(id, 32, 0.0));
    }
    e.run_to_completion(10_000).unwrap();
    let st = e.controller_state().unwrap();
    assert_eq!(st.gamma, 2);
    assert_eq!(st.switches, 0);
    assert_eq!(st.policy, "static");
    assert!(st.alpha_hat.is_some(), "estimates still maintained");
    assert!(st.intervals > 0);
}

#[test]
fn controller_slo_ceiling_caps_admissions() {
    // With a TPOT SLO and a controller, the measured cost table drives
    // the batch ceiling: a tight SLO must keep the running batch well
    // under max_batch, a loose one must not.
    let run_with_slo = |slo: Option<f64>| -> f64 {
        let (tsim, dsim) = sims();
        let backend = SyntheticLm::new(tsim, dsim, 0.9, 11);
        let mut e = Engine::new(
            EngineConfig {
                gamma: 3,
                kv: KvConfig {
                    num_blocks: 1 << 16,
                    block_size: 16,
                },
                scheduler: SchedulerConfig {
                    max_batch: 64,
                    admit_reserve_tokens: 32,
                    tpot_slo: slo,
                },
                buckets: Buckets::pow2_up_to(64),
                seed: 11,
                control: Some(ControlConfig::static_gamma(3)),
                ..Default::default()
            },
            backend,
        );
        for id in 0..64u64 {
            e.submit(req(id, 24, 0.0));
        }
        e.run_to_completion(100_000).unwrap();
        e.metrics.mean_batch()
    };
    let free = run_with_slo(None);
    // ~8 ms/token: satisfiable only at small batches on this platform.
    let tight = run_with_slo(Some(8e-3));
    assert!(
        free > 1.5 * tight,
        "tight SLO should shrink mean batch: free={free:.1} tight={tight:.1}"
    );
}

#[test]
fn control_config_kinds_construct() {
    let c = ControlConfig::static_gamma(4);
    assert!(matches!(c.policy, PolicyKind::Static { gamma: 4 }));
    let a = adaptive(0.8);
    assert!(matches!(a.policy, PolicyKind::ModelGuided { .. }));
}
