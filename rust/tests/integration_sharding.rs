//! Integration tests for the expert-parallel sharding subsystem: the
//! analytic topology sweep's monotonicity claims, engine-level serving on
//! sharded prices, and the control plane picking γ per topology.

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::control::{ControlConfig, CostModelSpec};
use moesd::engine::{Engine, EngineConfig};
use moesd::experiments::sharding::{self, Fabric};
use moesd::experiments::{run_pair, RunOpts};
use moesd::hardware::{platform_2x_gpu_a, Platform, ShardingSpec, Topology};
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;

/// The headline sweep: favorable batch range widens with sparsity × EP
/// degree and shrinks under a communication-bound fabric — the full
/// `check_shape` claim set over the real sweep output.
#[test]
fn sharding_sweep_monotonicity_claims_hold() {
    let out = sharding::run(3, 0.9);
    sharding::check_shape(&out).unwrap();

    // Acceptance spot-checks, stated directly: (a) more EP ranks extend
    // the largest SD-winning batch; (b) sparser experts extend it further;
    // (c) PCIe never beats NVLink on the payload-heavy K=8 axis.
    let edge = |f, d, k| sharding::crossover_batch(f, d, k, 3, 0.9);
    assert!(edge(Fabric::NvLink, 8, 8) > edge(Fabric::None, 1, 8));
    assert!(edge(Fabric::NvLink, 4, 4) > edge(Fabric::NvLink, 4, 8));
    assert!(edge(Fabric::Pcie, 4, 8) <= edge(Fabric::NvLink, 4, 8));
}

/// Engine-measured serving on an EP-sharded target: the virtual clock
/// prices the sharded deployment, so decode is absolutely faster and SD
/// still wins at a moderate batch.
#[test]
fn engine_runs_on_sharded_prices_and_sd_wins() {
    let target = presets::qwen2_57b_a14b();
    let draft = presets::qwen2_0_5b();
    let platform = platform_2x_gpu_a();
    let base_opts = RunOpts {
        max_new_tokens: 24,
        ..Default::default()
    };
    let sharded_opts = RunOpts {
        topology: Some(Topology::nvlink(4)),
        ..base_opts.clone()
    };
    let b = 32;
    let plain = run_pair(&target, &draft, &platform, 0.9, 3, b, &base_opts).unwrap();
    let ep = run_pair(&target, &draft, &platform, 0.9, 3, b, &sharded_opts).unwrap();

    assert!(ep.speedup > 1.5, "sharded SD should win at B={b}: {}", ep.speedup);
    assert!(ep.speedup < 3.2, "speedup out of band: {}", ep.speedup);
    // Four EP ranks are absolutely faster than one on both sides of the
    // speedup ratio (validated: ~2.5× on the decode forward at B=32).
    assert!(ep.t_ar < plain.t_ar, "EP t_ar {} vs {}", ep.t_ar, plain.t_ar);
    assert!(ep.t_sd < plain.t_sd, "EP t_sd {} vs {}", ep.t_sd, plain.t_sd);
    // The reported target efficiency is the sharded simulator's.
    let sim = ExecSim::new(target.clone(), platform.clone()).with_sharding(
        ShardingSpec::for_arch(Topology::nvlink(4), &target),
    );
    assert_eq!(ep.target_efficiency, sim.target_efficiency(b, 3, 512));
    assert!(
        ep.target_efficiency > plain.target_efficiency,
        "EP should lift teff at B={b}: {} vs {}",
        ep.target_efficiency,
        plain.target_efficiency
    );
}

/// The adaptive control plane, handed a topology-aware cost model, serves
/// losslessly on the sharded virtual clock and speculates at small batch.
#[test]
fn adaptive_controller_on_sharded_cost_model_stays_lossless() {
    let target = presets::qwen2_57b_a14b();
    let platform = platform_2x_gpu_a();
    let spec = ShardingSpec::for_arch(Topology::nvlink(4), &target);
    let tsim = ExecSim::new(target, platform.clone()).with_sharding(spec);
    let draft_platform = Platform::new(platform.gpu.clone(), 1, platform.interconnect_bw);
    let dsim = ExecSim::new(presets::qwen2_0_5b(), draft_platform);

    let config = EngineConfig {
        gamma: 0, // the controller owns γ from round 0
        control: Some(ControlConfig::model_guided(CostModelSpec::roofline(
            tsim.clone(),
            dsim.clone(),
        ))),
        ..Default::default()
    };
    let mut engine = Engine::new(config, SyntheticLm::new(tsim, dsim, 0.9, 17));
    for id in 0..4u64 {
        engine.submit(Request {
            id,
            prompt: (0..6u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 20,
                eos_token: None,
            },
            arrival: 0.0,
            class: 0,
        });
    }
    let done = engine.run_to_completion(1000).unwrap();
    assert_eq!(done.len(), 4);
    for c in &done {
        assert_eq!(c.tokens, engine.backend().expected_chain(c.id, 6, 20));
    }
    let st = engine.controller_state().unwrap();
    assert!(st.gamma >= 1, "small-batch EP serving should speculate: {st:?}");
}

/// The sweep's CSV surface carries every column the heatmap needs.
#[test]
fn sweep_csv_has_heatmap_columns() {
    let out = sharding::run(2, 0.85);
    for col in [
        "devices",
        "fabric",
        "link_gbps",
        "k",
        "batch",
        "target_efficiency",
        "speedup",
    ] {
        assert!(
            out.table.header.iter().any(|h| h == col),
            "missing column {col}"
        );
    }
    let speedups = out.table.column_f64("speedup").unwrap();
    assert_eq!(speedups.len(), out.points.len());
    assert!(speedups.iter().all(|x| x.is_finite() && *x > 0.0));
}
