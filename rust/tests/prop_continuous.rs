//! Property tests for the continuous-batching pipeline (PR 7):
//!
//! 1. **Degenerate parity** — the continuous engine with every feature
//!    off (no chunking, no draft-ahead, batch round boundaries) replays
//!    the lock-step `Engine::step` bit-for-bit: same tokens, same
//!    virtual clock, same rounds, same preemptions, same per-stage time
//!    accounting, across random workloads.
//! 2. **Losslessness under the full pipeline** — chunked prefill +
//!    draft-ahead + per-sequence boundaries still emit exactly the
//!    deterministic token chains.
//! 3. **Preempt-on-admission** — a high-priority arrival that cannot be
//!    admitted evicts a strictly-lower-tier running sequence (and the
//!    knob is off by default).

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::engine::{Engine, EngineConfig, PipelineConfig};
use moesd::hardware::platform_2x_gpu_a;
use moesd::kvcache::KvConfig;
use moesd::scheduler::{AdmissionPolicyConfig, ClassAwareConfig, SchedulerConfig};
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::testkit::{ensure, Gen, Runner};
use moesd::workload::TenantClass;

/// A random open-loop workload: staggered arrivals, random lengths.
struct Workload {
    alpha: f64,
    gamma: usize,
    max_batch: usize,
    blocks: usize,
    seed: u64,
    specs: Vec<(usize, usize, f64)>, // (prompt_len, max_new, arrival)
}

fn gen_workload(g: &mut Gen) -> Workload {
    let n_req = g.usize_in(1, 8);
    let mut t = 0.0;
    let specs = (0..n_req)
        .map(|_| {
            t += g.f64_in(0.0, 0.05);
            (g.usize_in(2, 12), g.usize_in(1, 24), t)
        })
        .collect();
    Workload {
        alpha: g.f64_in(0.4, 0.95),
        gamma: g.usize_in(0, 5),
        max_batch: g.usize_in(1, 6),
        blocks: g.usize_in(16, 64),
        seed: g.u64_in(0, 1 << 20),
        specs,
    }
}

fn build(w: &Workload, pipeline: PipelineConfig) -> Engine<SyntheticLm> {
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    let mut e = Engine::new(
        EngineConfig {
            gamma: w.gamma,
            kv: KvConfig {
                num_blocks: w.blocks,
                block_size: 4,
            },
            scheduler: SchedulerConfig {
                max_batch: w.max_batch,
                admit_reserve_tokens: 4,
                tpot_slo: None,
            },
            seed: w.seed,
            pipeline,
            ..Default::default()
        },
        SyntheticLm::new(target, draft, w.alpha, w.seed),
    );
    for (i, &(prompt_len, max_new, arrival)) in w.specs.iter().enumerate() {
        e.submit(Request {
            id: i as u64,
            prompt: (0..prompt_len as u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: max_new,
                eos_token: None,
            },
            arrival,
            class: 0,
        });
    }
    e
}

/// Everything the parity claim compares: per-request outcomes, virtual
/// clock, round/preemption counts, and the stage-time accounting.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    completions: Vec<(u64, Vec<u32>, f64, f64)>, // (id, tokens, ttft, finished_at)
    rounds: u64,
    clock: f64,
    preemptions: u64,
    time_draft: f64,
    time_verify: f64,
    time_reject: f64,
    time_prefill: f64,
}

fn run_fingerprint(w: &Workload, pipeline: PipelineConfig) -> Result<Fingerprint, String> {
    let mut e = build(w, pipeline);
    let mut done = e
        .run_to_completion(20_000)
        .map_err(|err| format!("run failed: {err}"))?;
    done.sort_by_key(|c| c.id);
    Ok(Fingerprint {
        completions: done
            .into_iter()
            .map(|c| (c.id, c.tokens, c.ttft(), c.finished_at))
            .collect(),
        rounds: e.metrics.rounds,
        clock: e.clock(),
        preemptions: e.counters.get("preemptions"),
        time_draft: e.metrics.time_draft,
        time_verify: e.metrics.time_verify,
        time_reject: e.metrics.time_reject,
        time_prefill: e.metrics.time_prefill,
    })
}

/// The degenerate continuous configuration: the pipeline dispatcher on,
/// every mechanism off.
fn degenerate() -> PipelineConfig {
    PipelineConfig {
        continuous: true,
        prefill_chunk: None,
        draft_ahead: false,
        per_seq_boundaries: false,
    }
}

#[test]
fn prop_degenerate_continuous_reproduces_lockstep_bit_for_bit() {
    let mut runner = Runner::new("continuous_degenerate_parity");
    runner.run(12, |g| {
        let w = gen_workload(g);
        let lockstep = run_fingerprint(&w, PipelineConfig::default())?;
        let cont = run_fingerprint(&w, degenerate())?;
        ensure(
            lockstep == cont,
            format!(
                "degenerate continuous diverged from lock-step:\n  lockstep: rounds {} \
                 clock {} preempt {} draft {} verify {} prefill {}\n  continuous: rounds {} \
                 clock {} preempt {} draft {} verify {} prefill {}",
                lockstep.rounds,
                lockstep.clock,
                lockstep.preemptions,
                lockstep.time_draft,
                lockstep.time_verify,
                lockstep.time_prefill,
                cont.rounds,
                cont.clock,
                cont.preemptions,
                cont.time_draft,
                cont.time_verify,
                cont.time_prefill,
            ),
        )
    });
}

#[test]
fn prop_full_pipeline_stays_lossless() {
    let mut runner = Runner::new("continuous_full_lossless");
    runner.run(12, |g| {
        let w = gen_workload(g);
        let chunk = g.usize_in(1, 16);
        let mut e = build(&w, PipelineConfig::full(chunk));
        let done = e
            .run_to_completion(40_000)
            .map_err(|err| format!("run failed: {err}"))?;
        if done.len() != w.specs.len() {
            return Err(format!("{} of {} completed", done.len(), w.specs.len()));
        }
        for c in &done {
            let (prompt_len, max_new, _) = w.specs[c.id as usize];
            if c.tokens.len() != max_new {
                return Err(format!(
                    "seq {}: {} tokens != {max_new}",
                    c.id,
                    c.tokens.len()
                ));
            }
            let expect = e.backend().expected_chain(c.id, prompt_len, max_new);
            if c.tokens != expect {
                return Err(format!(
                    "seq {}: wrong tokens (losslessness broken by the pipeline)",
                    c.id
                ));
            }
        }
        if let Err(err) = e.kv().check_invariants() {
            return Err(format!("KV invariant: {err}"));
        }
        // Accounting sanity: hidden draft time is a subset of draft time,
        // and the critical-path decode time never exceeds the stage sum.
        let m = &e.metrics;
        if m.time_draft_hidden > m.time_draft + 1e-12 {
            return Err(format!(
                "hidden draft {} exceeds total draft {}",
                m.time_draft_hidden, m.time_draft
            ));
        }
        if m.pipeline_decode_time() > m.decode_time() + 1e-12 {
            return Err("pipeline decode time exceeds stage sum".into());
        }
        ensure(true, "")
    });
}

fn two_tier_engine(preempt_on_admission: bool) -> Engine<SyntheticLm> {
    let bulk = TenantClass::new("bulk"); // priority 1
    let mut hi = TenantClass::new("hi");
    hi.priority = 2;
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    Engine::new(
        EngineConfig {
            gamma: 2,
            kv: KvConfig {
                // Two bulk sequences reserve 2×8 blocks; a third admission
                // needs 8 more and only 2 remain → admission stalls until
                // something is evicted or finishes.
                num_blocks: 18,
                block_size: 4,
            },
            scheduler: SchedulerConfig {
                max_batch: 8,
                admit_reserve_tokens: 24,
                tpot_slo: None,
            },
            seed: 7,
            tenants: vec![bulk, hi],
            admission: AdmissionPolicyConfig::ClassAware(ClassAwareConfig {
                preempt_on_admission,
                ..ClassAwareConfig::default()
            }),
            ..Default::default()
        },
        SyntheticLm::new(target, draft, 0.9, 7),
    )
}

fn two_tier_workload(e: &mut Engine<SyntheticLm>) {
    // Two long-running bulk sequences arrive first and claim the KV…
    for id in 0..2u64 {
        e.submit(Request {
            id,
            prompt: (0..8).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 24,
                eos_token: None,
            },
            arrival: 0.0,
            class: 0,
        });
    }
    // …then a high-priority request lands behind the full cache.
    e.submit(Request {
        id: 2,
        prompt: (0..8).collect(),
        params: SamplingParams {
            temperature: 0.0,
            max_new_tokens: 8,
            eos_token: None,
        },
        arrival: 1e-3,
        class: 1,
    });
}

#[test]
fn preemptive_eviction_on_admission_frees_room_for_high_priority() {
    let mut e = two_tier_engine(true);
    two_tier_workload(&mut e);
    let done = e.run_to_completion(20_000).unwrap();
    assert_eq!(done.len(), 3, "all requests complete despite the eviction");
    assert!(
        e.counters.get("admission_evictions") >= 1,
        "the high-priority arrival must evict a bulk sequence"
    );
    assert!(e.counters.get("preemptions") >= 1);
    // Losslessness survives the evict/restore cycle.
    for c in &done {
        let max_new = if c.id == 2 { 8 } else { 24 };
        assert_eq!(c.tokens, e.backend().expected_chain(c.id, 8, max_new));
    }
    // The high-priority request starts decoding before the bulk work
    // drains: its first token precedes at least one bulk completion.
    let hi = done.iter().find(|c| c.id == 2).unwrap();
    let bulk_last = done
        .iter()
        .filter(|c| c.id != 2)
        .map(|c| c.finished_at)
        .fold(f64::MIN, f64::max);
    assert!(
        hi.arrival + hi.ttft() < bulk_last,
        "hi TTFT {} should beat the last bulk completion {}",
        hi.arrival + hi.ttft(),
        bulk_last
    );
}

#[test]
fn admission_eviction_is_off_by_default() {
    assert!(!ClassAwareConfig::default().preempt_on_admission);
    let mut e = two_tier_engine(false);
    two_tier_workload(&mut e);
    let done = e.run_to_completion(20_000).unwrap();
    assert_eq!(done.len(), 3);
    assert_eq!(
        e.counters.get("admission_evictions"),
        0,
        "no admission-time eviction without the knob"
    );
}
