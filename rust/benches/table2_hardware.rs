//! Bench: regenerate Table 2 — peak SD speedup for Qwen2 across hardware
//! platforms (2×GPU-B, 4×GPU-A, 4×GPU-C), plus the two §4.1 observations.

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::tables;
use moesd::workload::Dataset;

fn main() {
    banner("table2_hardware", "Table 2");
    let t1 = tables::table1(42).unwrap();
    let rows = tables::table2(42).unwrap();
    let md = tables::render_markdown(&rows);
    println!("{md}");
    write_report("table2_hardware.md", &md).unwrap();
    write_report("table2_hardware.csv", &tables::to_csv(&rows).to_string()).unwrap();

    let mut checks = ShapeChecks::new();
    match tables::check_table2(&t1, &rows) {
        Ok(()) => checks.check("obs (1): higher-RP GPU-B beats GPU-A", true),
        Err(e) => {
            println!("shape error: {e}");
            checks.check("obs (1): higher-RP GPU-B beats GPU-A", false);
        }
    }
    // Observation (2): 4×GPU-A reduces absolute times vs 2×GPU-A but the
    // speedup slightly degrades (draft stays single-GPU).
    let r2 = t1
        .iter()
        .find(|r| r.model == "qwen2" && r.dataset == Dataset::HumanEval && r.temp == 0.0)
        .unwrap();
    let r4 = rows
        .iter()
        .find(|r| r.device == "4xGPU-A" && r.dataset == Dataset::HumanEval && r.temp == 0.0)
        .unwrap();
    let (t2ar, x2) = (r2.cells[2].t_ar, r2.cells[2].speedup);
    let (t4ar, x4) = (r4.cells[2].t_ar, r4.cells[2].speedup);
    println!("2xGPU-A: T_AR {t2ar:.3} x {x2:.2} | 4xGPU-A: T_AR {t4ar:.3} x {x4:.2}");
    checks.check("obs (2a): 4×GPU-A reduces absolute T_AR", t4ar < t2ar);
    checks.check("obs (2b): 4×GPU-A speedup degrades slightly", x4 < x2);
    // Every config still peaks above 1.0 on every platform.
    checks.check(
        "all configs have x > 1",
        rows.iter().all(|r| r.cells.iter().all(|c| c.speedup > 1.0)),
    );
    checks.finish("table2_hardware");
}
