//! Coordinator overhead of the distributed engine (PR 10).
//!
//! Runs the same decode-steady-state workload through the single-process
//! engine and through `DistBackend` on the in-process loopback transport
//! (1 draft + 2 verify ranks, pipelining on) at B ∈ {8, 32, 128}, and
//! prices what the message-passing coordinator adds per round: frame
//! encode/decode, channel hops, op-log append, in-flight bookkeeping.
//! Both runs execute bit-identical rounds (that is the conformance
//! suite's invariant), so the wall-clock delta is pure dist machinery.
//!
//! Assertion this bench gates every run: at B=32 the *whole* distributed
//! coordinator step — single-process scheduling plus all wire overhead —
//! stays under 5% of the simulated model step, the same §Perf budget
//! `micro_hotpath` holds for the local engine.
//!
//! Also reported (not gated): the drain-after-every-op (serial) round
//! time at B=32, i.e. what pipelining buys, and a striped-draft
//! (`draft_ranks=2`) round for the scale-out path.
//!
//! Output: `results/dist_overhead.{txt,json}`; full runs seed/refresh
//! the tracked `BENCH_dist_overhead.json` baseline (same rules as
//! `micro_hotpath`: smoke runs never write it).

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::benchlib::{
    banner, bench_record_json, compare_to_baseline, repo_path, summarize, time_reps,
    write_json_report, write_report, Json,
};
use moesd::dist::{DistBackend, DistConfig};
use moesd::engine::{Engine, EngineConfig};
use moesd::hardware::platform_2x_gpu_a;
use moesd::kvcache::KvConfig;
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::spec::SdBackend;
use moesd::util::stats;

fn synthetic() -> SyntheticLm {
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    SyntheticLm::new(target, draft, 0.9, 3)
}

fn dist(verify_ranks: usize, draft_ranks: usize, pipeline: bool) -> DistBackend<SyntheticLm> {
    DistBackend::launch(
        DistConfig {
            verify_ranks,
            draft_ranks,
            pipeline,
            ..Default::default()
        },
        move || -> anyhow::Result<SyntheticLm> { Ok(synthetic()) },
    )
    .expect("dist launch")
}

/// Decode-steady-state engine at the given batch, γ=4: B sequences that
/// never finish, prefilled and one round in.
fn steady<B: SdBackend>(backend: B, batch: usize) -> Engine<B> {
    let mut engine = Engine::new(
        EngineConfig {
            gamma: 4,
            kv: KvConfig {
                num_blocks: 1 << 14,
                block_size: 16,
            },
            scheduler: SchedulerConfig {
                max_batch: batch,
                admit_reserve_tokens: 1 << 12,
                tpot_slo: None,
            },
            ..Default::default()
        },
        backend,
    );
    for id in 0..batch as u64 {
        engine.submit(Request {
            id,
            prompt: (0..16u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 1 << 20, // never finishes during the bench
                eos_token: None,
            },
            arrival: 0.0,
            class: 0,
        });
    }
    engine.step().unwrap(); // prefill + first round
    engine
}

fn main() {
    banner("dist_overhead", "distributed coordinator cost per round");
    let smoke = std::env::var("MOESD_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    let scale: usize = if smoke { 20 } else { 1 };
    let reps = |n: usize| (n / scale).max(3);

    let mut lines: Vec<String> = Vec::new();
    let mut records: Vec<Json> = Vec::new();
    fn push(lines: &mut Vec<String>, records: &mut Vec<Json>, name: &str, secs: &[f64]) -> f64 {
        lines.push(summarize(name, secs));
        records.push(bench_record_json(name, secs));
        stats::mean(secs)
    }

    // --- single-process vs dist(loopback, pipelined) at each batch ----------
    // Returns (single wall, dist wall, simulated model step).
    let mut pair = |lines: &mut Vec<String>,
                    records: &mut Vec<Json>,
                    batch: usize,
                    warmup: usize,
                    n: usize|
     -> (f64, f64, f64) {
        let mut sp = steady(synthetic(), batch);
        let sp_secs = time_reps(
            || {
                sp.step().unwrap();
            },
            warmup,
            n,
        );
        let sp_wall = push(lines, records, &format!("engine_step_single_b{batch}"), &sp_secs);
        let sim_step = sp.metrics.decode_time() / sp.metrics.rounds as f64;

        let mut de = steady(dist(2, 1, true), batch);
        let d_secs = time_reps(
            || {
                de.step().unwrap();
            },
            warmup,
            n,
        );
        let d_wall = push(lines, records, &format!("engine_step_dist_b{batch}"), &d_secs);
        (sp_wall, d_wall, sim_step)
    };

    let (sp8, d8, sim8) = pair(&mut lines, &mut records, 8, reps(20), reps(300));
    let (sp32, d32, sim32) = pair(&mut lines, &mut records, 32, reps(20), reps(300));
    let (sp128, d128, sim128) = pair(&mut lines, &mut records, 128, reps(10), reps(100));

    for (batch, sp, d, sim) in [
        (8usize, sp8, d8, sim8),
        (32, sp32, d32, sim32),
        (128, sp128, d128, sim128),
    ] {
        let added = (d - sp).max(0.0);
        lines.push(format!(
            "  B={batch}: single {:.3}ms, dist {:.3}ms (+{:.3}ms wire) per round; \
             model step {:.3}ms; dist coordinator = {:.2}% of model time",
            sp * 1e3,
            d * 1e3,
            added * 1e3,
            sim * 1e3,
            d / sim * 100.0
        ));
    }

    // §Perf gate: at B=32 the full distributed coordinator round — local
    // scheduling plus encode/hop/decode/op-log — fits the same 5% budget
    // the local engine holds.
    let dist_ratio = d32 / sim32;
    assert!(
        dist_ratio < 0.05,
        "dist coordinator at B=32 is {:.2}% of the simulated model step \
         (budget: 5%); single-process was {:.2}%",
        dist_ratio * 100.0,
        sp32 / sim32 * 100.0
    );

    // --- context points at B=32 (reported, not gated) -----------------------
    // Serial coordinator: drain every op before the next — what the
    // pipelined in-flight window replaces.
    let serial32 = {
        let mut e = steady(dist(2, 1, false), 32);
        let secs = time_reps(
            || {
                e.step().unwrap();
            },
            reps(20),
            reps(300),
        );
        push(&mut lines, &mut records, "engine_step_dist_serial_b32", &secs)
    };
    // Striped drafting: propose sharded across 2 draft replicas.
    let striped32 = {
        let mut e = steady(dist(2, 2, true), 32);
        let secs = time_reps(
            || {
                e.step().unwrap();
            },
            reps(20),
            reps(300),
        );
        push(
            &mut lines,
            &mut records,
            "engine_step_dist_draft2_b32",
            &secs,
        )
    };
    lines.push(format!(
        "  B=32 context: serial (no pipelining) {:.3}ms vs pipelined {:.3}ms \
         ({:.2}x); striped draft_ranks=2 {:.3}ms",
        serial32 * 1e3,
        d32 * 1e3,
        serial32 / d32,
        striped32 * 1e3
    ));

    // --- reports ------------------------------------------------------------
    let report = lines.join("\n");
    println!("{report}");
    write_report("dist_overhead.txt", &report).unwrap();

    let json = Json::from_pairs(vec![
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str("dist_overhead".into())),
        ("populated", Json::Bool(true)),
        ("smoke", Json::Bool(smoke)),
        (
            "summary",
            Json::from_pairs(vec![
                ("single_step_wall_s_b8", Json::Num(sp8)),
                ("single_step_wall_s_b32", Json::Num(sp32)),
                ("single_step_wall_s_b128", Json::Num(sp128)),
                ("dist_step_wall_s_b8", Json::Num(d8)),
                ("dist_step_wall_s_b32", Json::Num(d32)),
                ("dist_step_wall_s_b128", Json::Num(d128)),
                ("dist_serial_step_wall_s_b32", Json::Num(serial32)),
                ("dist_draft2_step_wall_s_b32", Json::Num(striped32)),
                ("dist_pct_of_model_step_b32", Json::Num(dist_ratio * 100.0)),
            ]),
        ),
        ("metrics", Json::Arr(records)),
    ]);
    write_json_report("dist_overhead.json", &json).unwrap();

    // Perf-regression harness, same rules as micro_hotpath: compare
    // before maintenance; smoke uses 3x-wider bands and never writes the
    // baseline; MOESD_SKIP_BASELINE=1 opts out on foreign machines.
    let baseline = repo_path("BENCH_dist_overhead.json");
    let skip_cmp =
        std::env::var("MOESD_SKIP_BASELINE").map_or(false, |v| v != "0" && !v.is_empty());
    if !skip_cmp {
        if let Ok(base) = Json::parse_file(&baseline) {
            let (warn, fail) = if smoke { (0.15, 0.45) } else { (0.05, 0.15) };
            let report = compare_to_baseline(&json, &base, warn, fail);
            println!("{}", report.summary());
            for w in &report.warnings {
                println!("  perf WARN: {w}");
            }
            for f in &report.failures {
                println!("  perf FAIL: {f}");
            }
            assert!(
                report.failures.is_empty(),
                "dist_overhead regressed >{:.0}% vs BENCH_dist_overhead.json on {} metric(s) \
                 (MOESD_WRITE_BASELINE=1 rebaselines after an intentional change; \
                 MOESD_SKIP_BASELINE=1 skips on foreign machines): {:?}",
                fail * 100.0,
                report.failures.len(),
                report.failures
            );
        }
    }

    let force = std::env::var("MOESD_WRITE_BASELINE").map_or(false, |v| v != "0" && !v.is_empty());
    let unpopulated = Json::parse_file(&baseline)
        .ok()
        .and_then(|j| j.get("populated").and_then(Json::as_bool))
        != Some(true);
    if !smoke && (force || unpopulated) {
        std::fs::write(&baseline, json.to_pretty()).unwrap();
        println!("perf baseline written to {}", baseline.display());
    }
    println!("dist_overhead: done");
}
