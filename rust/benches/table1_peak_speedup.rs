//! Bench: regenerate Table 1 — peak SD speedup (x) with T_AR/T_SD/σ for
//! Qwen2 + Mixtral across datasets, temperatures and γ on 2×GPU-A.

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::tables;

fn main() {
    banner("table1_peak_speedup", "Table 1");
    let rows = tables::table1(42).unwrap();
    let md = tables::render_markdown(&rows);
    println!("{md}");
    write_report("table1_peak_speedup.md", &md).unwrap();
    write_report("table1_peak_speedup.csv", &tables::to_csv(&rows).to_string()).unwrap();

    let mut checks = ShapeChecks::new();
    match tables::check_table1(&rows) {
        Ok(()) => checks.check("table-1 orderings (γ↑, code>chat, x>1, moderate B)", true),
        Err(e) => {
            println!("shape error: {e}");
            checks.check("table-1 orderings", false);
        }
    }
    // Paper headline: Qwen2 humaneval T=0 γ=4 peaks at 2.18x on 2×GPU-A —
    // our simulated testbed should land in the same band.
    let headline = rows
        .iter()
        .find(|r| {
            r.model == "qwen2"
                && r.dataset == moesd::workload::Dataset::HumanEval
                && r.temp == 0.0
        })
        .unwrap()
        .cells[2]
        .speedup;
    println!("headline (qwen2/humaneval/T0/γ4): {headline:.2}x (paper: 2.18x)");
    checks.check(
        &format!("headline in band 1.6–3.6 ({headline:.2})"),
        headline > 1.6 && headline < 3.6,
    );
    checks.finish("table1_peak_speedup");
}
