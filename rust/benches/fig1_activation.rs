//! Bench: regenerate Fig. 1 — expert activation N(t) (theory vs sampled
//! routing) for DeepSeek-V2-Lite and Qwen1.5-MoE, plus T̄_exp(T; ρ).

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::fig1;
use moesd::theory;

fn main() {
    banner("fig1_activation", "Fig. 1(a)(b)(c)");
    let (a, b, c) = fig1::run(400, 42);

    println!("Fig 1a (DeepSeek-V2-Lite, ρ=6/62): tokens, N_theory, N_empirical");
    print!("{}", a.to_string());
    println!("Fig 1b (Qwen1.5-MoE, ρ=4/60):");
    print!("{}", b.to_string());

    write_report("fig1a_activation.csv", &a.to_string()).unwrap();
    write_report("fig1b_activation.csv", &b.to_string()).unwrap();
    write_report("fig1c_expert_load.csv", &c.to_string()).unwrap();

    let mut checks = ShapeChecks::new();
    // Theory matches sampled routing within 5% (the paper's Fig. 1a/b
    // overlap claim).
    for (name, table) in [("fig1a", &a), ("fig1b", &b)] {
        let theory_col = table.column_f64("theory").unwrap();
        let emp = table.column_f64("empirical").unwrap();
        let max_rel = theory_col
            .iter()
            .zip(&emp)
            .map(|(t, e)| (t - e).abs() / t.max(1.0))
            .fold(0.0f64, f64::max);
        checks.check(
            &format!("{name}: theory≈empirical (max rel {max_rel:.3})"),
            max_rel < 0.05,
        );
    }
    // T̄_exp monotone in ρ for every T column (Fig. 1c / App. B).
    for col in ["texp_norm_T8", "texp_norm_T32", "texp_norm_T128"] {
        let v = c.column_f64(col).unwrap();
        let monotone = v.windows(2).all(|w| w[1] >= w[0] - 1e-12);
        checks.check(&format!("{col} monotone in ρ"), monotone);
    }
    // Full-activation thresholds match the Eq. 9 closed form.
    checks.check(
        "T_thres(DeepSeek)=30, T_thres(Qwen1.5-MoE)=44 (τ=0.95)",
        theory::token_threshold(6.0 / 62.0, 0.95) == 30
            && theory::token_threshold(4.0 / 60.0, 0.95) == 44,
    );
    checks.finish("fig1_activation");
}
