//! Micro-benchmarks of the L3 coordinator hot paths (the §Perf targets):
//! KV-manager ops, rejection sampling at toy AND realistic vocabulary,
//! engine step overhead at B=32 on both the sparse `LogitsView` path and
//! the dense-rows reference (the pre-sparse hot path, kept in
//! `SyntheticLm::with_dense_rows`), and the perf-model fit time.
//!
//! Assertions this bench gates every run:
//! - coordinator wall/step < 5% of the simulated model step (§Perf), on
//!   the sparse path at vocab 64 *and* at Qwen2's real 151936;
//! - the sparse hot path is ≥ 5× faster than the dense-rows reference at
//!   realistic vocab, for both `verify_chain` and the full engine step.
//!
//! Output: human-readable `results/micro_hotpath.txt` and machine-readable
//! `results/micro_hotpath.json`. A **full** run additionally seeds the
//! tracked repo-root `BENCH_hotpath.json` baseline while it is
//! absent/unpopulated (or refreshes it under `MOESD_WRITE_BASELINE=1`).
//! `MOESD_SMOKE=1` (used by ci.sh) shrinks repetition counts ~20× and
//! never writes the baseline — smoke numbers are too noisy to track.

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::benchlib::{
    banner, bench_record_json, compare_to_baseline, repo_path, summarize, time_reps,
    write_json_report, write_report, Json,
};
use moesd::engine::{Engine, EngineConfig, PipelineConfig};
use moesd::hardware::platform_2x_gpu_a;
use moesd::kvcache::{KvConfig, KvManager};
use moesd::sampling::{verify_chain, verify_chain_views, LogitsView};
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::util::rng::Rng;
use moesd::util::stats;

const REAL_VOCAB: usize = 151_936;

fn dense_one_hot(tok: u32, vocab: usize) -> Vec<f64> {
    let mut row = vec![0.0; vocab];
    row[tok as usize] = 1.0;
    row
}

/// Build a decode-steady-state engine at B=32, γ=4 on the synthetic
/// backend (sparse or dense-rows reference) and the given vocab, under
/// the given pipeline mode (lock-step default or the continuous engine).
fn steady_engine(vocab: usize, dense_rows: bool, pipeline: PipelineConfig) -> Engine<SyntheticLm> {
    let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
    let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
    let mut backend = SyntheticLm::new(target, draft, 0.9, 3).with_vocab(vocab);
    if dense_rows {
        backend = backend.with_dense_rows();
    }
    let mut engine = Engine::new(
        EngineConfig {
            gamma: 4,
            kv: KvConfig {
                num_blocks: 1 << 14,
                block_size: 16,
            },
            scheduler: SchedulerConfig {
                max_batch: 32,
                admit_reserve_tokens: 1 << 12,
                tpot_slo: None,
            },
            pipeline,
            ..Default::default()
        },
        backend,
    );
    for id in 0..32u64 {
        engine.submit(Request {
            id,
            prompt: (0..16u32).collect(),
            params: SamplingParams {
                temperature: 0.0,
                max_new_tokens: 1 << 20, // never finishes during the bench
                eos_token: None,
            },
            arrival: 0.0,
            class: 0,
        });
    }
    engine.step().unwrap(); // prefill + first round
    engine
}

fn main() {
    banner("micro_hotpath", "§Perf L3 targets");
    let smoke = std::env::var("MOESD_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    let scale: usize = if smoke { 20 } else { 1 };
    let reps = |n: usize| (n / scale).max(3);

    let mut lines: Vec<String> = Vec::new();
    let mut records: Vec<Json> = Vec::new();
    fn push(lines: &mut Vec<String>, records: &mut Vec<Json>, name: &str, secs: &[f64]) -> f64 {
        lines.push(summarize(name, secs));
        records.push(bench_record_json(name, secs));
        stats::mean(secs)
    }

    // --- KV manager: allocate/append/truncate/release cycle ----------------
    let kv_mean = {
        let mut kv = KvManager::new(KvConfig {
            num_blocks: 4096,
            block_size: 16,
        });
        let mut id = 0u64;
        let secs = time_reps(
            || {
                kv.allocate(id, 64).unwrap();
                kv.append(id, 5).unwrap();
                kv.truncate(id, 66);
                kv.release(id);
                id += 1;
            },
            reps(1000),
            reps(20_000),
        );
        push(&mut lines, &mut records, "kv_alloc_append_truncate_release", &secs)
    };

    // --- rejection sampling: γ=4 chains, dense reference vs sparse views ----
    // Workload shape mirrors the synthetic backend: one-hot rows with the
    // first 3 proposals matching the target and the 4th rejected, so the
    // accept test, residual resampling, and the rejected-row walk are all
    // exercised. The dense rows at REAL_VOCAB are exactly what the
    // pre-sparse hot path allocated per round.
    let mut verify_pair = |vocab: usize, n_dense: usize, n_sparse: usize| -> (f64, f64) {
        let correct: Vec<u32> = vec![5, 6, 7, 8, 9]; // γ+1 chain rows
        let drafts: Vec<u32> = vec![5, 6, 7, 1]; // 3 hits, 1 miss
        // Dense reference.
        let draft_rows: Vec<Vec<f64>> =
            drafts.iter().map(|&t| dense_one_hot(t, vocab)).collect();
        let target_rows: Vec<Vec<f64>> =
            correct.iter().map(|&t| dense_one_hot(t, vocab)).collect();
        let mut rng = Rng::seeded(1);
        let dense_secs = time_reps(
            || {
                let out = verify_chain(&drafts, &draft_rows, &target_rows, &mut rng);
                std::hint::black_box(out);
            },
            n_dense / 10 + 1,
            n_dense,
        );
        let dense_mean = push(
            &mut lines,
            &mut records,
            &format!("verify_chain_dense_gamma4_vocab{vocab}"),
            &dense_secs,
        );
        // Sparse views.
        let draft_views: Vec<LogitsView> = drafts
            .iter()
            .map(|&t| LogitsView::one_hot(t, vocab))
            .collect();
        let target_views: Vec<LogitsView> = correct
            .iter()
            .map(|&t| LogitsView::one_hot(t, vocab))
            .collect();
        let mut rng = Rng::seeded(1);
        let sparse_secs = time_reps(
            || {
                let out = verify_chain_views(&drafts, &draft_views, &target_views, &mut rng);
                std::hint::black_box(out);
            },
            n_sparse / 10 + 1,
            n_sparse,
        );
        let sparse_mean = push(
            &mut lines,
            &mut records,
            &format!("verify_chain_sparse_gamma4_vocab{vocab}"),
            &sparse_secs,
        );
        (dense_mean, sparse_mean)
    };
    let (_d64, _s64) = verify_pair(64, reps(50_000), reps(50_000));
    let (d_real, s_real) = verify_pair(REAL_VOCAB, reps(2_000), reps(50_000));
    let vc_speedup = d_real / s_real;
    lines.push(format!(
        "  verify_chain sparse-vs-dense speedup at vocab {REAL_VOCAB}: {vc_speedup:.1}x"
    ));
    assert!(
        vc_speedup >= 5.0,
        "sparse verify_chain should be >= 5x the dense path at realistic vocab, \
         got {vc_speedup:.1}x"
    );

    // --- engine step at B=32, γ=4: sparse path vs dense-rows reference ------
    let mut engine_bench = |vocab: usize,
                            dense_rows: bool,
                            pipeline: PipelineConfig,
                            warmup: usize,
                            n: usize,
                            name: &str|
     -> (f64, f64) {
        let mut engine = steady_engine(vocab, dense_rows, pipeline);
        let secs = time_reps(
            || {
                engine.step().unwrap();
            },
            warmup,
            n,
        );
        let sim_step = engine.metrics.decode_time() / engine.metrics.rounds as f64;
        let wall = push(&mut lines, &mut records, name, &secs);
        (wall, sim_step)
    };
    // Sparse path (the serving default), toy + realistic vocab.
    let (wall64, sim64) = engine_bench(
        64,
        false,
        PipelineConfig::default(),
        reps(20),
        reps(300),
        "engine_step_b32_gamma4 (wall)",
    );
    let (wall_real, sim_real) = engine_bench(
        REAL_VOCAB,
        false,
        PipelineConfig::default(),
        reps(20),
        reps(300),
        "engine_step_b32_gamma4_vocab151936 (wall)",
    );
    // Continuous pipeline (chunked prefill + draft-ahead + per-seq
    // boundaries) at the same shapes: the event-driven step must hold
    // the same coordinator budget as the lock-step round.
    let (wall_cont, sim_cont) = engine_bench(
        64,
        false,
        PipelineConfig::full(512),
        reps(20),
        reps(300),
        "engine_step_continuous_full_b32 (wall)",
    );
    // Dense-rows reference (pre-sparse hot path), same shapes.
    let (dense64, _) = engine_bench(
        64,
        true,
        PipelineConfig::default(),
        reps(20),
        reps(300),
        "engine_step_dense_rows_vocab64 (wall)",
    );
    let (dense_real, _) = engine_bench(
        REAL_VOCAB,
        true,
        PipelineConfig::default(),
        1,
        if smoke { 3 } else { 20 },
        "engine_step_dense_rows_vocab151936 (wall)",
    );

    let step_speedup_64 = dense64 / wall64;
    let step_speedup_real = dense_real / wall_real;
    for (vocab, wall, sim, speedup) in [
        (64usize, wall64, sim64, step_speedup_64),
        (REAL_VOCAB, wall_real, sim_real, step_speedup_real),
    ] {
        let ratio = wall / sim;
        lines.push(format!(
            "  vocab {vocab}: simulated model step = {:.3}ms; coordinator wall/step = {:.3}ms \
             ({:.2}% of model time); {speedup:.1}x vs dense-rows reference",
            sim * 1e3,
            wall * 1e3,
            ratio * 100.0
        ));
        // §Perf target: < 5% of the simulated step at B=32 — now also
        // enforced in the regime the tentpole unlocked.
        assert!(
            ratio < 0.05,
            "L3 overhead {:.2}% exceeds the 5% §Perf budget at vocab {vocab}",
            ratio * 100.0
        );
    }
    // The continuous engine's per-step bookkeeping (phase tracking,
    // cohort selection, chunk draws) must fit the same budget.
    {
        let ratio = wall_cont / sim_cont;
        lines.push(format!(
            "  continuous full pipeline: simulated model step = {:.3}ms; coordinator \
             wall/step = {:.3}ms ({:.2}% of model time)",
            sim_cont * 1e3,
            wall_cont * 1e3,
            ratio * 100.0
        ));
        assert!(
            ratio < 0.05,
            "continuous-engine overhead {:.2}% exceeds the 5% §Perf budget",
            ratio * 100.0
        );
    }
    assert!(
        step_speedup_real >= 5.0,
        "sparse engine step should be >= 5x the dense-rows reference at realistic vocab, \
         got {step_speedup_real:.1}x"
    );

    // --- perf-model fit time -------------------------------------------------
    {
        use moesd::fit::fit_perfmodel;
        use moesd::perfmodel::*;
        let model = PerfModel::with_ridge_point(150.0);
        let truth = PerfParams {
            bias: 0.02,
            k1: 3e-5,
            k2: 2.5e-4,
            k3: 2e-4,
            draft_bias: 0.0015,
            draft_k: 1e-5,
            reject_bias: 2e-4,
            reject_k: 1e-7,
            lambda: 0.55,
            s: 1.03,
        };
        let ms: Vec<Measurement> = (0..21)
            .map(|i| {
                let mut m = Measurement {
                    batch: 1 + 5 * i,
                    gamma: 2 + (i % 2) * 2,
                    k: [2, 4, 8][i % 3],
                    e: 64,
                    sigma: 0.88,
                    speedup: 0.0,
                };
                m.speedup = model.compute_speedup(&truth, &m);
                m
            })
            .collect();
        let bounds = ParamBounds {
            lo: [1e-3, 0.0, 1e-6, 0.0, 1e-5, 0.0, 0.0, 0.0, 0.2, 1.0 + 1e-9],
            hi: [0.1, 1.0, 1e-2, 1.0, 0.01, 1.0, 1e-2, 1e-4, 1.0, 2.0],
        };
        let secs = time_reps(
            || {
                let (p, _) = fit_perfmodel(&model, &ms, &bounds, 3);
                std::hint::black_box(p);
            },
            1,
            if smoke { 2 } else { 5 },
        );
        push(&mut lines, &mut records, "perfmodel_fit_21_measurements", &secs);
    }

    // --- reports -------------------------------------------------------------
    let report = lines.join("\n");
    println!("{report}");
    write_report("micro_hotpath.txt", &report).unwrap();

    let json = Json::from_pairs(vec![
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str("micro_hotpath".into())),
        ("populated", Json::Bool(true)),
        ("smoke", Json::Bool(smoke)),
        (
            "summary",
            Json::from_pairs(vec![
                ("kv_cycle_ops_per_s", Json::Num(1.0 / kv_mean)),
                (
                    "verify_chain_sparse_speedup_vocab151936",
                    Json::Num(vc_speedup),
                ),
                ("engine_step_wall_s_vocab64", Json::Num(wall64)),
                ("engine_step_wall_s_vocab151936", Json::Num(wall_real)),
                ("engine_step_continuous_wall_s", Json::Num(wall_cont)),
                (
                    "engine_step_sparse_speedup_vocab64",
                    Json::Num(step_speedup_64),
                ),
                (
                    "engine_step_sparse_speedup_vocab151936",
                    Json::Num(step_speedup_real),
                ),
            ]),
        ),
        ("metrics", Json::Arr(records)),
    ]);
    write_json_report("micro_hotpath.json", &json).unwrap();

    // Perf-regression harness: compare this run against the tracked
    // baseline BEFORE any baseline maintenance, so a refresh can't mask
    // a regression. Full runs use the tight bands (fail > 15%, warn
    // > 5%); the MOESD_SMOKE=1 ci.sh gate still fails hard but at 3×
    // wider bands — its 20×-reduced reps carry real scheduler jitter,
    // and a flaky perf gate trains people to ignore it.
    // MOESD_SKIP_BASELINE=1 opts out on machines the baseline wasn't
    // measured on.
    let baseline = repo_path("BENCH_hotpath.json");
    let skip_cmp =
        std::env::var("MOESD_SKIP_BASELINE").map_or(false, |v| v != "0" && !v.is_empty());
    if !skip_cmp {
        if let Ok(base) = Json::parse_file(&baseline) {
            let (warn, fail) = if smoke { (0.15, 0.45) } else { (0.05, 0.15) };
            let report = compare_to_baseline(&json, &base, warn, fail);
            println!("{}", report.summary());
            for w in &report.warnings {
                println!("  perf WARN: {w}");
            }
            for f in &report.failures {
                println!("  perf FAIL: {f}");
            }
            assert!(
                report.failures.is_empty(),
                "micro_hotpath regressed >{:.0}% vs BENCH_hotpath.json on {} metric(s) \
                 (MOESD_WRITE_BASELINE=1 rebaselines after an intentional change; \
                 MOESD_SKIP_BASELINE=1 skips on foreign machines): {:?}",
                fail * 100.0,
                report.failures.len(),
                report.failures
            );
        }
    }

    // Maintain the tracked repo-root baseline. Smoke runs (ci.sh) never
    // touch it — their 20x-reduced reps are too noisy to anchor a perf
    // trajectory and would dirty every checkout CI runs on. A *full*
    // bench run seeds it while it is absent/unpopulated;
    // MOESD_WRITE_BASELINE=1 forces a refresh (full runs only).
    let force = std::env::var("MOESD_WRITE_BASELINE").map_or(false, |v| v != "0" && !v.is_empty());
    let unpopulated = Json::parse_file(&baseline)
        .ok()
        .and_then(|j| j.get("populated").and_then(Json::as_bool))
        != Some(true);
    if !smoke && (force || unpopulated) {
        std::fs::write(&baseline, json.to_pretty()).unwrap();
        println!("perf baseline written to {}", baseline.display());
    }
    println!("micro_hotpath: done");
}
