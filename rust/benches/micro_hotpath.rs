//! Micro-benchmarks of the L3 coordinator hot paths (the §Perf targets):
//! KV-manager ops, rejection sampling, engine step overhead at B=32, and
//! the perf-model fit time (paper: ~0.1 s for 21 points).

use moesd::arch::presets;
use moesd::batching::{Request, SamplingParams};
use moesd::benchlib::{banner, summarize, time_reps, write_report};
use moesd::engine::{Engine, EngineConfig};
use moesd::hardware::platform_2x_gpu_a;
use moesd::kvcache::{KvConfig, KvManager};
use moesd::sampling::verify_chain;
use moesd::scheduler::SchedulerConfig;
use moesd::simulator::ExecSim;
use moesd::spec::synthetic::SyntheticLm;
use moesd::util::rng::Rng;

fn main() {
    banner("micro_hotpath", "§Perf L3 targets");
    let mut lines = Vec::new();

    // --- KV manager: allocate/append/truncate/release cycle ----------------
    {
        let mut kv = KvManager::new(KvConfig {
            num_blocks: 4096,
            block_size: 16,
        });
        let mut id = 0u64;
        let secs = time_reps(
            || {
                kv.allocate(id, 64).unwrap();
                kv.append(id, 5).unwrap();
                kv.truncate(id, 66);
                kv.release(id);
                id += 1;
            },
            1000,
            20_000,
        );
        lines.push(summarize("kv_alloc_append_truncate_release", &secs));
    }

    // --- rejection sampling: one γ=4 chain over vocab 64 --------------------
    {
        let mut rng = Rng::seeded(1);
        let dist: Vec<f64> = (0..64).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let sum: f64 = dist.iter().sum();
        let dist: Vec<f64> = dist.iter().map(|v| v / sum).collect();
        let draft_probs = vec![dist.clone(); 4];
        let target_probs = vec![dist.clone(); 5];
        let tokens = [1u32, 2, 3, 4];
        let secs = time_reps(
            || {
                let out = verify_chain(&tokens, &draft_probs, &target_probs, &mut rng);
                std::hint::black_box(out);
            },
            1000,
            50_000,
        );
        lines.push(summarize("verify_chain_gamma4_vocab64", &secs));
    }

    // --- engine step overhead at B=32 ---------------------------------------
    // The §Perf criterion: coordinator overhead per step must be well
    // under the simulated model time (tens of ms at this scale).
    {
        let target = ExecSim::new(presets::qwen2_57b_a14b(), platform_2x_gpu_a());
        let draft = ExecSim::new(presets::qwen2_0_5b(), platform_2x_gpu_a());
        let backend = SyntheticLm::new(target, draft, 0.9, 3);
        let mut engine = Engine::new(
            EngineConfig {
                gamma: 4,
                kv: KvConfig {
                    num_blocks: 1 << 14,
                    block_size: 16,
                },
                scheduler: SchedulerConfig {
                    max_batch: 32,
                    admit_reserve_tokens: 1 << 12,
                    tpot_slo: None,
                },
                ..Default::default()
            },
            backend,
        );
        for id in 0..32u64 {
            engine.submit(Request {
                id,
                prompt: (0..16u32).collect(),
                params: SamplingParams {
                    temperature: 0.0,
                    max_new_tokens: 1 << 20, // never finishes during bench
                    eos_token: None,
                },
                arrival: 0.0,
            });
        }
        engine.step().unwrap(); // prefill + first round
        let secs = time_reps(
            || {
                engine.step().unwrap();
            },
            20,
            300,
        );
        lines.push(summarize("engine_step_b32_gamma4 (wall)", &secs));
        let sim_step = engine.metrics.decode_time() / engine.metrics.rounds as f64;
        let wall_mean = moesd::util::stats::mean(&secs);
        let ratio = wall_mean / sim_step;
        lines.push(format!(
            "  simulated model step = {:.3}ms; coordinator wall/step = {:.3}ms ({:.1}% of model time)",
            sim_step * 1e3,
            wall_mean * 1e3,
            ratio * 100.0
        ));
        // §Perf target: < 5% of the simulated step at B=32.
        assert!(
            ratio < 0.05,
            "L3 overhead {:.2}% exceeds the 5% §Perf budget",
            ratio * 100.0
        );
    }

    // --- perf-model fit time -------------------------------------------------
    {
        use moesd::fit::fit_perfmodel;
        use moesd::perfmodel::*;
        let model = PerfModel::with_ridge_point(150.0);
        let truth = PerfParams {
            bias: 0.02,
            k1: 3e-5,
            k2: 2.5e-4,
            k3: 2e-4,
            draft_bias: 0.0015,
            draft_k: 1e-5,
            reject_bias: 2e-4,
            reject_k: 1e-7,
            lambda: 0.55,
            s: 1.03,
        };
        let ms: Vec<Measurement> = (0..21)
            .map(|i| {
                let mut m = Measurement {
                    batch: 1 + 5 * i,
                    gamma: 2 + (i % 2) * 2,
                    k: [2, 4, 8][i % 3],
                    e: 64,
                    sigma: 0.88,
                    speedup: 0.0,
                };
                m.speedup = model.compute_speedup(&truth, &m);
                m
            })
            .collect();
        let bounds = ParamBounds {
            lo: [1e-3, 0.0, 1e-6, 0.0, 1e-5, 0.0, 0.0, 0.0, 0.2, 1.0 + 1e-9],
            hi: [0.1, 1.0, 1e-2, 1.0, 0.01, 1.0, 1e-2, 1e-4, 1.0, 2.0],
        };
        let secs = time_reps(
            || {
                let (p, _) = fit_perfmodel(&model, &ms, &bounds, 3);
                std::hint::black_box(p);
            },
            1,
            5,
        );
        lines.push(summarize("perfmodel_fit_21_measurements", &secs));
    }

    let report = lines.join("\n");
    println!("{report}");
    write_report("micro_hotpath.txt", &report).unwrap();
    println!("micro_hotpath: done");
}
