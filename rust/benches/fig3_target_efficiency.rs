//! Bench: regenerate Fig. 3 — target efficiency comparison MoE vs dense.

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::fig3;

fn main() {
    banner("fig3_target_efficiency", "Fig. 3");
    let out = fig3::run(3);
    print!("{}", out.table.to_string());
    write_report("fig3_target_efficiency.csv", &out.table.to_string()).unwrap();

    let mut checks = ShapeChecks::new();
    match fig3::check_shape(&out) {
        Ok(()) => checks.check("MoE rises-then-falls; dense only falls; crossover", true),
        Err(e) => {
            println!("shape error: {e}");
            checks.check("MoE rises-then-falls; dense only falls; crossover", false);
        }
    }
    checks.finish("fig3_target_efficiency");
}
