//! Bench: regenerate Fig. 2 — SD speedup (and target efficiency) vs batch
//! size across platform/model panels, measured by the serving engine on
//! the roofline-simulated virtual clock.

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::fig2::{check_shape, default_panels, panel_csv, sweep_panel};
use moesd::experiments::peak_speedup;

fn main() {
    banner("fig2_speedup", "Fig. 2");
    let mut checks = ShapeChecks::new();
    let mut all_csv = String::new();
    for (i, panel) in default_panels().iter().enumerate() {
        let stats = sweep_panel(panel, 42 + i as u64).unwrap();
        let csv = panel_csv(panel, &stats);
        if i == 0 {
            all_csv.push_str(&csv.to_string());
        } else {
            // Skip repeated header.
            let s = csv.to_string();
            all_csv.push_str(s.split_once('\n').unwrap().1);
        }
        let peak = peak_speedup(&stats);
        println!(
            "panel {} [{} on {} / {} T={} γ={}]: peak x={:.2} at B={} (teff {:.2})",
            i,
            panel.model,
            panel.platform,
            panel.dataset.name(),
            panel.temp,
            panel.gamma,
            peak.speedup,
            peak.batch,
            peak.target_efficiency
        );
        for s in &stats {
            println!(
                "  B={:>3}  speedup={:.3}  target_eff={:.3}  σ={:.3}",
                s.batch, s.speedup, s.target_efficiency, s.sigma
            );
        }
        match check_shape(&stats) {
            Ok(()) => checks.check(&format!("panel {i}: rise-then-fall + teff tracks"), true),
            Err(e) => {
                println!("  shape error: {e}");
                checks.check(&format!("panel {i}: rise-then-fall + teff tracks"), false);
            }
        }
        checks.check(
            &format!("panel {i}: peak at moderate batch"),
            peak.batch >= 4 && peak.batch <= 80,
        );
    }
    write_report("fig2_speedup.csv", &all_csv).unwrap();
    checks.finish("fig2_speedup");
}
