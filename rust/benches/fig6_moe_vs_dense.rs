//! Bench: regenerate Fig. 6 — end-to-end SD speedup, MoE vs dense, across
//! dataset × temperature panels (App. A.2).

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::fig6;
use moesd::workload::Dataset;

fn main() {
    banner("fig6_moe_vs_dense", "Fig. 6 / App. A.2");
    let mut checks = ShapeChecks::new();
    let mut panels = Vec::new();
    for ds in [Dataset::HumanEval, Dataset::MtBench] {
        for temp in [0.0, 1.0] {
            panels.push((ds, temp));
        }
    }
    let mut relative_gain_t0 = 0.0;
    let mut relative_gain_t1 = 0.0;
    for (i, (ds, temp)) in panels.iter().enumerate() {
        let out = fig6::run(*ds, *temp, 3, 21 + i as u64).unwrap();
        write_report(
            &format!("fig6_{}_t{}.csv", ds.name(), *temp as u32),
            &out.table.to_string(),
        )
        .unwrap();
        let moe_peak = out.moe.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let dense_peak = out.dense.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        println!(
            "panel [{} T={temp}]: MoE peak {moe_peak:.2} vs dense peak {dense_peak:.2}",
            ds.name()
        );
        match fig6::check_shape(&out) {
            Ok(()) => checks.check(
                &format!("{} T={temp}: MoE rise/fall, dense decay, MoE wins B≥16", ds.name()),
                true,
            ),
            Err(e) => {
                println!("  shape error: {e}");
                checks.check(&format!("{} T={temp}: shape", ds.name()), false);
            }
        }
        // Track the relative MoE advantage per temperature (App. A.2's
        // second observation).
        let adv = moe_peak / dense_peak;
        if *ds == Dataset::HumanEval {
            if *temp == 0.0 {
                relative_gain_t0 = adv;
            } else {
                relative_gain_t1 = adv;
            }
        }
    }
    println!(
        "MoE/dense peak-speedup ratio (humaneval): T=0 {relative_gain_t0:.2}, T=1 {relative_gain_t1:.2}"
    );
    checks.finish("fig6_moe_vs_dense");
}
