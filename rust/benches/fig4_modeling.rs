//! Bench: regenerate Fig. 4 — analytic model vs measurement across MoE
//! sparsity K ∈ {1,2,4,8,16,32} and γ ∈ {2,4}, fit on the paper's m=21
//! stride-11 subsample, plus the peak-shift / plateau-width claims.

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::fig4;
use moesd::perfmodel::PerfParams;

fn main() {
    banner("fig4_modeling", "Fig. 4 (+ Alg. 1 fit)");
    let t0 = std::time::Instant::now();
    let out = fig4::run(0.88, 7).unwrap();
    println!(
        "fit on {} measurements: fit MSE {:.4}, full-grid MSE {:.4} ({} points) in {:.2}s",
        out.fit_count,
        out.fit_mse,
        out.full_mse,
        out.points.len(),
        t0.elapsed().as_secs_f64()
    );
    let names = PerfParams::names();
    for (name, v) in names.iter().zip(out.params.to_vec()) {
        println!("  {name:12} = {v:.6e}");
    }
    write_report("fig4_model_vs_measured.csv", &fig4::to_csv(&out).to_string()).unwrap();

    let mut checks = ShapeChecks::new();
    checks.check(
        &format!("228-point grid (got {})", out.points.len()),
        out.points.len() == 228,
    );
    checks.check(&format!("m=21 fit (got {})", out.fit_count), out.fit_count == 21);
    checks.check(
        &format!("model tracks measurement (full MSE {:.4})", out.full_mse),
        out.full_mse < 0.15,
    );

    // §4.2 observations: for the FFN-dominated variants (K ≥ 4), sparser
    // (smaller K) peaks at a larger batch and holds a wider x/√2 plateau;
    // the artificially attention-dominated K=1 variant instead decays
    // (the paper's Amdahl anomaly).
    for gamma in fig4::GAMMAS {
        let p8 = fig4::peak_batch(&out.points, 8, gamma);
        let p4 = fig4::peak_batch(&out.points, 4, gamma);
        println!("γ={gamma}: peak batch K=8 → {p8}, K=4 → {p4}");
        checks.check(
            &format!("γ={gamma}: sparser peaks later (K4 {p4} ≥ K8 {p8})"),
            p4 >= p8,
        );
        let w8 = fig4::plateau_width(&out.points, 8, gamma);
        let w4 = fig4::plateau_width(&out.points, 4, gamma);
        println!("γ={gamma}: x/√2 plateau width K=8 → {w8}, K=4 → {w4}");
        checks.check(
            &format!("γ={gamma}: sparser plateau wider (K4 {w4} ≥ K8 {w8})"),
            w4 >= w8,
        );
        // K=1 anomaly: the peak sits at a small batch (≤ 8) because the
        // MoE FFN no longer dominates (Amdahl's law, §4.2).
        let p1 = fig4::peak_batch(&out.points, 1, gamma);
        checks.check(
            &format!("γ={gamma}: K=1 anomaly — early peak at B={p1} ≤ 8"),
            p1 <= 8,
        );
    }

    // Per-(K, γ) correlation between modeled and measured series.
    for &k in &fig4::K_VALUES {
        for gamma in fig4::GAMMAS {
            let series: Vec<&fig4::GridPoint> = out
                .points
                .iter()
                .filter(|p| p.k == k && p.gamma == gamma)
                .collect();
            let measured: Vec<f64> = series.iter().map(|p| p.measured).collect();
            let modeled: Vec<f64> = series.iter().map(|p| p.modeled).collect();
            let r = moesd::util::stats::pearson(&measured, &modeled);
            checks.check(&format!("K={k} γ={gamma}: model/measured r={r:.3} > 0.8"), r > 0.8);
        }
    }
    checks.finish("fig4_modeling");
}
