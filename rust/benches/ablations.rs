//! Bench: ablations beyond the paper's main grid (§3.4 extended
//! configurations + the limitation section): expert parallelism, routing
//! imbalance, and the KV-dominant (MagicDec) regime.

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::ablations;
use moesd::util::csv::CsvTable;

fn main() {
    banner("ablations", "§3.4 extended configs + §5 limitation");
    let mut checks = ShapeChecks::new();

    // --- EP scaling ---------------------------------------------------------
    let ep = ablations::ep_scaling(&[2, 4, 8, 16], 4);
    let mut csv = CsvTable::new(&["n_gpus", "teff_b1", "teff_b32"]);
    println!("expert parallelism (γ=4):");
    for (n, t1, t32) in &ep {
        println!("  {n:>2} GPUs: teff(B=1) {t1:.3}  teff(B=32) {t32:.3}");
        csv.push_nums(&[*n as f64, *t1, *t32]);
    }
    write_report("ablation_ep_scaling.csv", &csv.to_string()).unwrap();
    checks.check(
        "EP lifts small-batch target efficiency (the §3.4 'vanishing inefficiency')",
        ep.last().unwrap().1 > ep.first().unwrap().1 + 0.02,
    );

    // --- routing imbalance ---------------------------------------------------
    let imb = ablations::imbalance_activation(&[0.05, 0.5, 10.0], &[8, 32, 128], 7);
    write_report("ablation_imbalance.csv", &imb.to_string()).unwrap();
    let skew = imb.column_f64("n_skewed").unwrap();
    let bal = imb.column_f64("n_balanced").unwrap();
    println!("\nrouting imbalance (E=64, K=8): Dirichlet α → N(32) skewed vs Eq.8");
    for row in &imb.rows {
        println!("  α={:<5} t={:<4} balanced {:<6} skewed {}", row[0], row[1], row[2], row[3]);
    }
    // Heavy skew at t=32 is the second row of the α=0.05 block (index 1).
    checks.check(
        "heavy imbalance under-activates experts vs Eq. 8",
        skew[1] < bal[1] - 4.0,
    );
    let n = skew.len();
    checks.check(
        "near-uniform router matches Eq. 8 (±10%)",
        (skew[n - 2] - bal[n - 2]).abs() / bal[n - 2] < 0.1,
    );

    // --- KV-dominant regime ---------------------------------------------------
    let kv = ablations::kv_dominant_regime(&[512, 2048, 8192, 32768, 131072], 256, 4);
    let mut csv = CsvTable::new(&["ctx", "teff_b256"]);
    println!("\nKV-dominant regime (B=256, γ=4):");
    for (ctx, teff) in &kv {
        println!("  ctx {ctx:>7}: teff {teff:.3}");
        csv.push_nums(&[*ctx as f64, *teff]);
    }
    write_report("ablation_kv_dominant.csv", &csv.to_string()).unwrap();
    checks.check(
        "long context restores target efficiency at large batch (MagicDec handoff)",
        kv.last().unwrap().1 > kv.first().unwrap().1 + 0.1,
    );

    checks.finish("ablations");
}
