//! Bench: regenerate Table 3 (+ Figs. 8–28) — fit MSE vs measurement
//! count m under stride subsampling, on the full 228-point grid.

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::{fig4, table3};

fn main() {
    banner("table3_fit_mse", "Table 3 / App. C.3");
    let t0 = std::time::Instant::now();
    let grid = fig4::measure_grid(0.88, 7).unwrap();
    println!("measured {} grid points in {:.1}s", grid.len(), t0.elapsed().as_secs_f64());
    let out = table3::run_on_grid(&grid, 11);
    println!("{:>5} {:>7} {:>9}  batch coverage", "m", "stride", "MSE");
    for r in &out.rows {
        println!(
            "{:>5} {:>7} {:>9.4}  {} sizes",
            r.m,
            r.stride,
            r.mse,
            r.batch_coverage.len()
        );
    }
    write_report("table3_fit_mse.csv", &table3::to_csv(&out).to_string()).unwrap();

    let mut checks = ShapeChecks::new();
    checks.check(
        &format!("{} stride rows computed", out.rows.len()),
        out.rows.len() >= 20,
    );
    match table3::check_shape(&out) {
        Ok(()) => checks.check("m≥21 fits stable", true),
        Err(e) => {
            println!("shape error: {e}");
            checks.check("m≥21 fits stable", false);
        }
    }
    // App. C.3's coverage observation: the m=12 and m=13 selections lose
    // batch-size coverage relative to m=11 (structural property of stride
    // sampling on the sorted grid).
    let cov = |m: usize| {
        out.rows
            .iter()
            .find(|r| r.m == m)
            .map(|r| r.batch_coverage.len())
            .unwrap_or(0)
    };
    println!(
        "batch coverage: m=11 → {}, m=12 → {}, m=13 → {}",
        cov(11),
        cov(12),
        cov(13)
    );
    checks.check(
        "m=12/13 selections lose coverage vs full grid",
        cov(12) < 19 && cov(13) < 19,
    );
    checks.finish("table3_fit_mse");
}
