//! Bench: regenerate Fig. 5 — speedup trends across more settings with 5
//! individual noisy runs + mean (incl. the tile-quantization sawtooth).

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::fig5;
use moesd::workload::Dataset;

fn main() {
    banner("fig5_trends", "Fig. 5 / App. A.1");
    let mut checks = ShapeChecks::new();
    let settings = [
        ("qwen2", "2xGPU-A", Dataset::HumanEval, 1.0, 4),
        ("qwen2", "2xGPU-B", Dataset::MtBench, 0.0, 3),
        ("mixtral", "2xGPU-A", Dataset::HumanEval, 0.0, 2),
        ("mixtral", "2xGPU-A", Dataset::MtBench, 1.0, 3),
    ];
    for (i, (model, platform, ds, temp, gamma)) in settings.iter().enumerate() {
        let out = fig5::run(model, platform, *ds, *temp, *gamma, 5).unwrap();
        println!(
            "panel {i} [{model} {platform} {} T={temp} γ={gamma}]: mean peak {:.2}, run σ {:.4}",
            ds.name(),
            out.mean_speedups
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max),
            out.run_stddev
        );
        write_report(&format!("fig5_panel{i}.csv"), &out.table.to_string()).unwrap();
        match fig5::check_shape(&out) {
            Ok(()) => checks.check(&format!("panel {i}: shape + low run variance"), true),
            Err(e) => {
                println!("  shape error: {e}");
                checks.check(&format!("panel {i}: shape + low run variance"), false);
            }
        }
    }
    checks.finish("fig5_trends");
}
