//! Bench: the adaptive speculation control plane on a traffic ramp.
//!
//! Sweeps concurrency B = 1 → 512 (closed-loop phases) and compares the
//! model-guided adaptive γ policy against every static-γ baseline,
//! asserting the control plane's headline claims: within 5% of the best
//! static oracle in every phase, strictly above the worst static γ
//! everywhere, and a demonstrated γ=0 fallback once the platform goes
//! compute-bound.

use moesd::benchlib::{banner, write_report, ShapeChecks};
use moesd::experiments::adaptive::{check_shape, ramp_batches, run, static_gammas, to_csv};

fn main() {
    banner(
        "adaptive_control",
        "§3 operationalized: online γ/batch co-tuning",
    );
    let alpha = 0.85;
    let out = run(alpha, 42).unwrap();

    // Render the per-phase matrix (policies × phases).
    let mut policies: Vec<String> = static_gammas()
        .iter()
        .map(|g| format!("static-{g}"))
        .collect();
    policies.push("adaptive".to_string());
    print!("{:>12}", "policy");
    for b in ramp_batches() {
        print!("  {:>9}", format!("B={b}"));
    }
    println!();
    for p in &policies {
        print!("{p:>12}");
        for b in ramp_batches() {
            let row = out
                .rows
                .iter()
                .find(|r| r.policy == *p && r.batch == b)
                .unwrap();
            print!("  {:>9.1}", row.tok_s);
        }
        println!();
    }
    for b in ramp_batches() {
        let row = out
            .rows
            .iter()
            .find(|r| r.policy == "adaptive" && r.batch == b)
            .unwrap();
        println!(
            "  phase B={b:>3}: adaptive γ_end={} ar_bulk_rounds={} α̂={:.3}",
            row.gamma_end, row.ar_bulk_rounds, row.alpha_hat
        );
    }

    write_report("adaptive_ramp.csv", &to_csv(&out).to_string()).unwrap();

    let mut checks = ShapeChecks::new();
    match check_shape(&out) {
        Ok(()) => checks.check("adaptive tracks best static γ in every phase", true),
        Err(e) => {
            println!("  {e}");
            checks.check(&format!("shape claim failed: {e}"), false);
        }
    }
    // Additionally: no single static γ is best in every phase (the
    // motivation for a control plane at all).
    let mut any_static_dominates = false;
    for g in static_gammas() {
        let label = format!("static-{g}");
        let dominates = ramp_batches().iter().all(|&b| {
            let this = out
                .rows
                .iter()
                .find(|r| r.policy == label && r.batch == b)
                .unwrap()
                .tok_s;
            out.rows
                .iter()
                .filter(|r| r.batch == b && r.policy != label)
                .all(|r| this >= r.tok_s * 0.999)
        });
        any_static_dominates |= dominates;
    }
    checks.check(
        "no static γ dominates the whole ramp",
        !any_static_dominates,
    );
    checks.finish("adaptive_control");
}
