//! Bench: the expert-parallel topology sweep — SD speedup × batch ×
//! EP degree × sparsity heatmap data (§3.4's scale axis), with the
//! monotonicity claims asserted as shape checks.

use moesd::benchlib::{banner, write_json_report, write_report, Json, ShapeChecks};
use moesd::experiments::sharding::{self, Fabric};

fn main() {
    banner("sharding_topology", "§3.4 EP configurations");
    let (gamma, alpha) = (3usize, 0.9f64);
    let out = sharding::run(gamma, alpha);
    write_report("sharding_sweep.csv", &out.table.to_string()).unwrap();

    // Per-configuration summary: peak speedup and the SD-favorable edge.
    let mut summary_rows: Vec<Json> = Vec::new();
    for &(fabric, d) in &sharding::default_configs() {
        for &k in &sharding::TOPK_SWEEP {
            let series: Vec<&sharding::ShardPoint> = out
                .points
                .iter()
                .filter(|p| p.fabric == fabric && p.devices == d && p.k == k)
                .collect();
            let peak = series
                .iter()
                .map(|p| p.speedup)
                .fold(f64::NEG_INFINITY, f64::max);
            let edge = sharding::crossover_batch(fabric, d, k, gamma, alpha);
            println!(
                "{:>6} d={d} K={k}: peak {:.2}x, SD-favorable up to B≈{edge}",
                fabric.name(),
                peak
            );
            summary_rows.push(Json::from_pairs(vec![
                ("fabric", fabric.name().into()),
                ("devices", d.into()),
                ("k", k.into()),
                ("peak_speedup", peak.into()),
                ("favorable_edge", edge.into()),
            ]));
        }
    }
    let json = Json::from_pairs(vec![
        ("bench", Json::Str("sharding_topology".into())),
        ("gamma", gamma.into()),
        ("alpha", alpha.into()),
        ("summary", Json::Arr(summary_rows)),
    ]);
    write_json_report("sharding_sweep.json", &json).unwrap();

    let mut checks = ShapeChecks::new();
    match sharding::check_shape(&out) {
        Ok(()) => checks.check("EP/sparsity widen, comm-bound narrows", true),
        Err(e) => {
            println!("shape error: {e}");
            checks.check("EP/sparsity widen, comm-bound narrows", false);
        }
    }
    checks.check(
        "8-way NVLink extends K=8 edge past one rank",
        sharding::crossover_batch(Fabric::NvLink, 8, 8, gamma, alpha)
            > sharding::crossover_batch(Fabric::None, 1, 8, gamma, alpha),
    );
    checks.finish("sharding_topology");
}
