#!/usr/bin/env python3
"""Python replica of the `moesd bench continuous` sweep (PR 7).

Independently re-implements, from the Rust sources:

  * the PCG-XSH-RR 64/32 RNG (`util/rng.rs`) — bit-exact,
  * the MMPP prefill-heavy trace (`workload/mod.rs`
    `synthetic_production_heavy`) — bit-exact arrival/length stream,
  * the roofline pricing walk (`simulator/mod.rs` `forward_time_tokens`,
    unsharded path) for qwen2-57B-A14B on 2×GPU-A and qwen2-0.5B on
    1×GPU-A, plus the SyntheticLm backend prices (`spec/synthetic.rs`):
    bulk prefill, batched chunk ops, uniform propose, packed verify,
    reject rows,
  * the lock-step round loop (`engine/mod.rs::step_lockstep`) and the
    continuous pipeline (`engine/continuous.rs`): batched chunked
    prefill with residual-charged registration, draft-ahead overlap
    budgets, per-sequence boundaries with the 1/2 coalescing guard, and
    the exact acceptance-RNG stream (`Rng(engine_seed ^ round_counter,
    13)`, per-sequence Bernoulli(α) draws with an extra `below(63)` on
    each failure).

It replays the same (load × arm) grid as
`rust/src/experiments/continuous.rs` and prints the cross-arm ratios the
bench's `check_shape` margins are calibrated against. KV capacity is not
modeled — the bench provisions 2^20 KV tokens for a ≤32 batch, so the
cache never binds and no preemption occurs (asserted in the Rust run by
`preemptions == 0` staying absent from counters).

Run:  python3 python/replica_continuous.py            # default grid
      python3 python/replica_continuous.py --seeds 42,7,11
"""

import argparse
from collections import deque
from functools import lru_cache

M64 = (1 << 64) - 1
M32 = (1 << 32) - 1
PCG_MULT = 6364136223846793005


class Rng:
    """PCG-XSH-RR 64/32, two 32-bit draws per u64 (util/rng.rs)."""

    def __init__(self, seed, stream):
        self.state = 0
        self.inc = ((stream << 1) | 1) & M64
        self.next_u32()
        self.state = (self.state + seed) & M64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & M32
        rot = (old >> 59) & 31
        return ((xorshifted >> rot) | (xorshifted << (32 - rot))) & M32 if rot else xorshifted

    def next_u64(self):
        hi = self.next_u32()
        lo = self.next_u32()
        return ((hi << 32) | lo) & M64

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        while True:
            x = self.next_u64()
            m = x * n
            low = m & M64
            if low >= n:
                return m >> 64
            threshold = ((M64 + 1) - n) % n
            if low >= threshold:
                return m >> 64

    def bernoulli(self, p):
        return self.f64() < p

    def normal(self):
        import math

        while True:
            u1 = self.f64()
            if u1 > 1e-300:
                u2 = self.f64()
                return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def exponential(self, rate):
        import math

        return -math.log(max(self.f64(), 1e-300)) / rate


# ---------------------------------------------------------------------------
# Trace (workload/mod.rs synthetic_production_heavy → synthetic_mmpp)
# ---------------------------------------------------------------------------

HEAVY = dict(plm=256.0, pls=0.6, olm=32.0, ols=0.5, corr=0.6,
             pclamp=(32, 1024), oclamp=(4, 128))


def sample_lengths(rng, m):
    import math

    z_in = rng.normal()
    eps = rng.normal()
    rho = m["corr"]
    z_out = rho * z_in + (1.0 - rho * rho) ** 0.5 * eps
    p = math.exp(math.log(m["plm"]) + m["pls"] * z_in)
    o = math.exp(math.log(m["olm"]) + m["ols"] * z_out)
    clamp = lambda v, lo, hi: min(max(int(v), lo), hi)
    return clamp(p, *m["pclamp"]), clamp(o, *m["oclamp"])


def synthetic_heavy(duration_s, base_rate, seed):
    rng = Rng(seed, 0x7ACE)
    events = []
    t = 0.0
    bursting = False
    state_end = rng.exponential(1.0 / 20.0)
    while t < duration_s:
        rate = 4.0 * base_rate if bursting else base_rate
        t += rng.exponential(rate)
        while t > state_end:
            bursting = not bursting
            state_end += rng.exponential(1.0 / 5.0 if bursting else 1.0 / 20.0)
        if t >= duration_s:
            break
        p, o = sample_lengths(rng, HEAVY)
        events.append((t, p, o))
    return events


# ---------------------------------------------------------------------------
# Roofline pricing (simulator/mod.rs, unsharded; hardware/mod.rs gpu_a)
# ---------------------------------------------------------------------------

EFF_C, EFF_M = 0.35, 0.80


class Plat:
    def __init__(self, n):
        self.n = n
        self.flops = 312e12 * n
        self.bw = 2039e9 * n
        self.ic = 300e9
        self.lat = 10e-6

    def op(self, flops, wbytes, abytes):
        return max(flops / (self.flops * EFF_C),
                   wbytes / (self.bw * EFF_M) + abytes / (self.bw * EFF_M))

    def allreduce(self, nbytes):
        if self.n <= 1:
            return 0.0
        return self.lat + 2.0 * (self.n - 1) / self.n * nbytes / self.ic


class Arch:
    def __init__(self, h, layers, heads, kv_heads, hd, vocab, moe=None, inter=None):
        self.h, self.layers, self.heads, self.kv_heads, self.hd = h, layers, heads, kv_heads, hd
        self.vocab, self.moe, self.inter = vocab, moe, inter
        self.dt = 2.0
        q = h * heads * hd
        kv = 2 * h * kv_heads * hd
        o = heads * hd * h
        self.attn_params = q + kv + o
        self.kv_bytes_tok = 2 * layers * kv_heads * hd * self.dt
        self.step_overhead = 150e-6 + layers * 40e-6


TARGET = Arch(3584, 28, 28, 4, 128, 151936, moe=(64, 8, 2560, 20480))
DRAFT = Arch(896, 24, 14, 2, 64, 151936, inter=4864)
TPLAT, DPLAT = Plat(2), Plat(1)


def fwd(arch, plat, b, tokens, ctx):
    assert b > 0 and tokens > 0
    t = float(tokens)
    dt, h, L = arch.dt, float(arch.h), float(arch.layers)
    total = plat.op(0.0, 0.0, t * h * dt) + arch.step_overhead
    attn_flops = t * (2.0 * arch.attn_params + 4.0 * arch.heads * arch.hd * ctx)
    kv_read = b * ctx * arch.kv_bytes_tok / L
    total += L * plat.op(attn_flops, arch.attn_params * dt, kv_read + 4.0 * t * h * dt)
    if arch.moe:
        E, K, ei, si = arch.moe
        total += L * (plat.op(t * 2.0 * h * E, h * E * dt, t * h * dt)
                      + plat.op(t * 6.0 * h * si, 3.0 * h * si * dt, 2.0 * t * h * dt))
        n_act = E * (1.0 - ((E - K) / E) ** t)
        load = t * K / max(n_act, 1e-9)
        total += L * plat.op(n_act * load * 6.0 * h * ei,
                             n_act * 3.0 * h * ei * dt,
                             2.0 * t * K * h * dt)
    else:
        inter = arch.inter
        total += L * plat.op(t * 6.0 * h * inter, 3.0 * h * inter * dt, 2.0 * t * h * dt)
    total += L * 2.0 * plat.allreduce(t * h * dt)
    total += plat.op(t * 2.0 * h * arch.vocab, arch.vocab * h * dt, t * arch.vocab * dt)
    return total


@lru_cache(maxsize=None)
def tT(b, tokens, ctx):
    return fwd(TARGET, TPLAT, b, tokens, ctx)


@lru_cache(maxsize=None)
def tD(b, tokens, ctx):
    return fwd(DRAFT, DPLAT, b, tokens, ctx)


CTX = 512  # SyntheticLm::ctx_for_pricing
GAMMA = 4
ALPHA = 0.9
MAX_BATCH = 32
SYNTH_VOCAB = 64


def prefill_cost(prompt_lens):
    maxp = max(p - 1 for p in prompt_lens)
    if maxp == 0:
        return 0.0
    b = len(prompt_lens)
    return tT(b, b * maxp, maxp) + tD(b, b * maxp, maxp)


def chunk_op_cost(parts):  # [(tokens, ctx)] — SyntheticLm::prefill_chunks_cost
    total = sum(tok for tok, _ in parts)
    if total == 0:
        return 0.0
    b = len(parts)
    cmax = max(c + tok for tok, c in parts)
    return tT(b, total, cmax) + tD(b, total, cmax)


def propose_cost(b):  # uniform γ: γ sequential single-token draft forwards
    return GAMMA * tD(b, b, CTX)


def verify_cost(b, rows):
    return tT(b, rows, CTX)


def reject_cost(rows):
    return 40e-6 + rows * TARGET.vocab * 4.0 / TPLAT.bw


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------


class Seq:
    __slots__ = ("rid", "plen", "need", "arrival", "gen", "first")

    def __init__(self, rid, plen, need, arrival):
        self.rid, self.plen, self.need, self.arrival = rid, plen, need, arrival
        self.gen = 0
        self.first = None


def draw_accepts(rng, n):
    """One propose call's acceptance draws for n sequences (γ=4 each):
    per g a Bernoulli(α), plus a below(vocab-1) draw on each failure.
    Accepted = leading success run (greedy verify)."""
    accs = []
    for _ in range(n):
        acc, run = 0, True
        for _ in range(GAMMA):
            if rng.bernoulli(ALPHA):
                if run:
                    acc += 1
            else:
                run = False
                rng.below(SYNTH_VOCAB - 1)
        accs.append(acc)
    return accs


class Metrics:
    def __init__(self):
        self.tokens = 0
        self.rounds = 0
        self.batch_sum = 0
        self.t_draft = self.t_hidden = self.t_verify = self.t_reject = self.t_prefill = 0.0
        self.chunks = 0
        self.done = []  # (arrival, first, finished, n_tokens)


class Lockstep:
    def __init__(self, reqs, seed):
        self.queue = deque(reqs)
        self.running = []
        self.clock = 0.0
        self.rc = 0
        self.stream = seed
        self.m = Metrics()

    def idle(self):
        return not self.queue and not self.running

    def step(self):
        if not self.running and self.queue and self.queue[0].arrival > self.clock:
            self.clock = self.queue[0].arrival
        admitted = []
        while (self.queue and len(self.running) + len(admitted) < MAX_BATCH
               and self.queue[0].arrival <= self.clock):
            admitted.append(self.queue.popleft())
        if admitted:
            cost = prefill_cost([r.plen for r in admitted])
            self.clock += cost
            self.m.t_prefill += cost
            self.running.extend(admitted)
        if not self.running:
            return
        b = len(self.running)
        self.m.rounds += 1
        self.m.batch_sum += b
        self.rc += 1
        accs = draw_accepts(Rng((self.stream ^ self.rc) & M64, 13), b)
        d, v, r = propose_cost(b), verify_cost(b, (GAMMA + 1) * b), reject_cost((GAMMA + 1) * b)
        self.clock += d + v + r
        self.m.t_draft += d
        self.m.t_verify += v
        self.m.t_reject += r
        still = []
        for s, acc in zip(self.running, accs):
            if s.first is None:
                s.first = self.clock
            emit = min(acc + 1, s.need - s.gen)
            s.gen += emit
            self.m.tokens += emit
            if s.gen >= s.need:
                self.m.done.append((s.arrival, s.first, self.clock, s.need))
            else:
                still.append(s)
        self.running = still


def select_cohort(cands, t_floor, per_seq):
    """(index, ready_at) candidates → (members, t_start). Port of
    engine/continuous.rs::select_cohort."""
    if not cands:
        return [], t_floor
    if not per_seq:
        t = t_floor
        for _, r in cands:
            t = max(t, r)
        return [i for i, _ in cands], t
    cut = t_floor
    if not any(r <= cut for _, r in cands):
        cut = min(r for _, r in cands)
    included = [(i, r) for i, r in cands if r <= cut]
    if len(included) * 2 < len(cands):
        included = list(cands)
    t = t_floor
    for _, r in included:
        t = max(t, r)
    return [i for i, _ in included], t


class Continuous:
    def __init__(self, reqs, seed, chunk, ahead, per_seq):
        self.queue = deque(reqs)
        self.running = []
        self.phases = []  # dicts: state, ready_at, ahead / acc+gamma when drafted
        self.prefilling = []  # [seq, done, paid]
        self.clock = 0.0
        self.free_d = self.free_t = 0.0
        self.budget = 0.0
        self.rc = 0
        self.stream = seed
        self.chunk, self.ahead, self.per_seq = chunk, ahead, per_seq
        self.m = Metrics()

    def idle(self):
        return not self.queue and not self.running and not self.prefilling

    def advance_serial(self, cost):
        t_end = max(self.free_d, self.free_t) + cost
        self.free_d = self.free_t = t_end
        self.clock = max(self.clock, t_end)
        return t_end

    def step(self):
        if not self.running and not self.prefilling:
            if self.queue and self.queue[0].arrival > self.clock:
                self.clock = self.queue[0].arrival
            self.free_d = max(self.free_d, self.clock)
            self.free_t = max(self.free_t, self.clock)
        self.admit()
        self.chunk_work()
        if not self.running:
            return
        self.propose_op()
        self.verify_commit_op()

    def admit(self):
        admitted = []
        while (self.queue
               and len(self.running) + len(self.prefilling) + len(admitted) < MAX_BATCH
               and self.queue[0].arrival <= self.clock):
            admitted.append(self.queue.popleft())
        if not admitted:
            return
        if self.chunk is None:
            cost = prefill_cost([r.plen for r in admitted])
            t_end = self.advance_serial(cost)
            self.m.t_prefill += cost
            for r in admitted:
                self.running.append(r)
                self.phases.append({"st": "ready", "t": t_end, "ah": False})
        else:
            for r in admitted:
                self.prefilling.append([r, 0, 0.0])

    def register_ready(self):
        ready = [e for e in self.prefilling if e[1] >= e[0].plen - 1]
        if not ready:
            return
        self.prefilling = [e for e in self.prefilling if e[1] < e[0].plen - 1]
        cost = prefill_cost([e[0].plen for e in ready])
        paid = sum(e[2] for e in ready)
        residual = max(cost - paid, 0.0)
        if residual > 0.0:
            self.advance_serial(residual)
            self.m.t_prefill += residual
        ready_at = max(self.free_d, self.free_t)
        for e in ready:
            self.running.append(e[0])
            self.phases.append({"st": "ready", "t": ready_at, "ah": False})

    def chunk_work(self):
        if self.chunk is None:
            return
        ops = 0
        while True:
            self.register_ready()
            draws = []
            left = max(self.chunk, 1)
            for e in self.prefilling:
                if left == 0:
                    break
                take = min(left, e[0].plen - 1 - e[1])
                draws.append((e, take))
                left -= take
            if not draws:
                break
            if ops >= 1 and self.running:
                break
            cost = chunk_op_cost([(take, e[1]) for e, take in draws])
            total = sum(take for _, take in draws)
            for e, take in draws:
                e[1] += take
                e[2] += cost * take / total
            self.advance_serial(cost)
            self.m.t_prefill += cost
            self.m.chunks += len(draws)
            ops += 1

    def propose_op(self):
        if not self.per_seq and any(p["st"] == "drafted" for p in self.phases):
            return
        cands = [(i, p["t"]) for i, p in enumerate(self.phases) if p["st"] == "ready"]
        t_floor = self.free_d if self.ahead else max(self.free_d, self.free_t)
        members, _ = select_cohort(cands, t_floor, self.per_seq)
        if not members:
            return
        b = len(members)
        self.rc += 1
        ready_max = max(self.phases[i]["t"] for i in members)
        t_start = max(t_floor, ready_max)
        elig = ([k for k in range(b) if self.phases[members[k]]["ah"]]
                if self.ahead else [])
        if not elig or len(elig) == b:
            accs = draw_accepts(Rng((self.stream ^ self.rc) & M64, 13), b)
            cost = propose_cost(b)
            hidden = min(cost, self.budget) if elig else 0.0
            total_cost = cost
        else:
            rest = [k for k in range(b) if k not in elig]
            accs = [0] * b
            total_cost, hidden = 0.0, 0.0
            for sub, overlapped in ((elig, True), (rest, False)):
                sub_accs = draw_accepts(Rng((self.stream ^ self.rc) & M64, 13), len(sub))
                self.rc += 1
                cost = propose_cost(len(sub))
                total_cost += cost
                if overlapped:
                    hidden = min(cost, self.budget)
                for slot, a in zip(sub, sub_accs):
                    accs[slot] = a
        self.budget -= hidden
        exposed = total_cost - hidden
        self.m.t_draft += total_cost
        self.m.t_hidden += hidden
        t_end = t_start + exposed
        self.free_d = max(self.free_d, t_end)
        if not self.ahead:
            self.free_t = max(self.free_t, t_end)
            self.clock = max(self.clock, t_end)
        for k, i in enumerate(members):
            self.phases[i] = {"st": "drafted", "t": t_end, "acc": accs[k]}

    def verify_commit_op(self):
        cands = [(i, p["t"]) for i, p in enumerate(self.phases) if p["st"] == "drafted"]
        if not cands:
            return
        t_floor = self.free_t if self.ahead else max(self.free_t, self.free_d)
        members, t_start = select_cohort(cands, t_floor, self.per_seq)
        if not members:
            return
        b = len(members)
        v = verify_cost(b, (GAMMA + 1) * b)
        r = reject_cost((GAMMA + 1) * b)
        t_end = t_start + v + r
        self.free_t = t_end
        if not self.ahead:
            self.free_d = max(self.free_d, t_end)
        self.clock = max(self.clock, t_end)
        self.budget = v
        self.m.t_verify += v
        self.m.t_reject += r
        self.m.rounds += 1
        self.m.batch_sum += b
        finished = []
        for i in members:
            s = self.running[i]
            acc = self.phases[i]["acc"]
            if s.first is None:
                s.first = self.clock
            emit = min(acc + 1, s.need - s.gen)
            s.gen += emit
            self.m.tokens += emit
            full = acc == GAMMA
            self.phases[i] = {"st": "ready", "t": t_end, "ah": self.ahead and full}
            if s.gen >= s.need:
                finished.append(i)
        for i in reversed(finished):
            s = self.running.pop(i)
            self.phases.pop(i)
            self.m.done.append((s.arrival, s.first, self.clock, s.need))


# ---------------------------------------------------------------------------
# Sweep
# ---------------------------------------------------------------------------


def pct(xs, q):
    if not xs:
        return 0.0
    xs = sorted(xs)
    import math

    rank = min(max(int(math.ceil(q * len(xs))), 1), len(xs))
    return xs[rank - 1]


def run_arm(events, load, seed, arm, chunk):
    scaled = [(t / load, p, o) for t, p, o in events]
    horizon = max(scaled[-1][0], 1e-6)
    reqs = [Seq(i, p, o, t) for i, (t, p, o) in enumerate(scaled)]
    if arm == "lockstep":
        e = Lockstep(reqs, seed)
    else:
        ahead = arm in ("+draft-ahead", "full")
        per_seq = arm == "full"
        e = Continuous(reqs, seed, chunk, ahead, per_seq)
    guard = 0
    while not e.idle() and e.clock < horizon:
        e.step()
        guard += 1
        assert guard < 400_000, "step guard"
    m = e.m
    clock = max(e.clock, 1e-9)
    ttfts = [f - a for a, f, _, _ in m.done]
    tpots = [(fin - f) / (n - 1) if n > 1 else 0.0 for _, f, fin, n in m.done]
    return dict(
        arm=arm, load=load, completed=len(m.done), tokens=m.tokens, clock=clock,
        goodput=m.tokens / clock,
        ttft_mean=sum(ttfts) / len(ttfts) if ttfts else 0.0,
        ttft_p99=pct(ttfts, 0.99),
        tpot_mean=sum(tpots) / len(tpots) if tpots else 0.0,
        tpot_p99=pct(tpots, 0.99),
        hidden_frac=m.t_hidden / m.t_draft if m.t_draft > 0 else 0.0,
        chunks=m.chunks,
        prefill_s=m.t_prefill,
    )


ARMS = ["lockstep", "+chunked", "+draft-ahead", "full"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", default="42,7,11")
    ap.add_argument("--loads", default="0.5,1.5,3.0")
    ap.add_argument("--chunk", type=int, default=512)
    args = ap.parse_args()
    seeds = [int(s) for s in args.seeds.split(",")]
    loads = [float(s) for s in args.loads.split(",")]

    for seed in seeds:
        events = synthetic_heavy(120.0, 4.0, seed)
        print(f"\n=== seed {seed}: {len(events)} events, "
              f"mean prompt {sum(p for _, p, _ in events) / len(events):.0f}, "
              f"mean output {sum(o for _, _, o in events) / len(events):.1f} ===")
        for load in loads:
            rows = {arm: run_arm(events, load, seed, arm, args.chunk) for arm in ARMS}
            base = rows["lockstep"]
            print(f"  load {load}x  (offered {len(events)} in {120.0 / load:.0f}s)")
            for arm in ARMS:
                r = rows[arm]
                rel = "" if arm == "lockstep" else (
                    f"   [vs lockstep: ttft_p99 {r['ttft_p99'] / max(base['ttft_p99'], 1e-12):.3f}x"
                    f" tpot {r['tpot_mean'] / max(base['tpot_mean'], 1e-12):.3f}x"
                    f" goodput {r['goodput'] / max(base['goodput'], 1e-12):.3f}x]")
                print(f"    {arm:>12}: done {r['completed']:>4} ttft p99 {r['ttft_p99']:8.3f}s"
                      f" mean {r['ttft_mean']:7.3f}s tpot {r['tpot_mean']:.5f}s"
                      f" goodput {r['goodput']:8.1f} tok/s hid {r['hidden_frac']:.2f}"
                      f" prefill {r['prefill_s']:6.1f}s{rel}")


if __name__ == "__main__":
    main()
