#!/usr/bin/env python3
"""Python replica of the `moesd bench budget` sweep (PR 8).

Independently re-implements, from the Rust sources, the expected-value
round model of the expert-budgeted speculative decoding trade:

  * the roofline pricing walk (`simulator/mod.rs`
    `forward_time_tokens_budgeted`, unsharded path) for qwen2-57B-A14B
    on 2xGPU-A and qwen2-0.5B on 1xGPU-A, with the routed-expert arm
    capped at the verify budget: `n_act = min(N(t), budget)` (Eq. 8
    capped), per-expert load recomputed against the capped count
    (Eq. 10), dispatch traffic unchanged;
  * the SyntheticLm round prices (`spec/synthetic.rs`): uniform propose
    (gamma sequential draft forwards), packed budgeted verify, reject
    rows at CTX = 512;
  * the acceptance-vs-budget degradation curve
    (`theory::budgeted_alpha`): alpha_eff = alpha * cov**sensitivity,
    cov = min(1, budget / N(t)) at verify width t = B*(gamma+1);
  * the expected emitted tokens per sequence per round,
    sum_{j=0..gamma} alpha_eff^j = (1 - alpha_eff^(gamma+1)) /
    (1 - alpha_eff).

It sweeps the same (alpha, K, B, budget, gamma) grid as
`rust/src/experiments/budget.rs` and prints, per point, the best
unbudgeted arm, the best budgeted arm, and their ratio — the margins
the bench's `check_shape` and `rust/tests/integration_budget.rs` pin
are calibrated against these numbers (the Rust runs measure the real
engine with stochastic acceptance, so pinned margins sit well below
the expected-value ratios printed here).

Run:  python3 python/replica_budget.py
      python3 python/replica_budget.py --sens 0.25 --full
"""

import argparse
from functools import lru_cache

# ---------------------------------------------------------------------------
# Roofline pricing (simulator/mod.rs, unsharded; hardware/mod.rs gpu_a)
# ---------------------------------------------------------------------------

EFF_C, EFF_M = 0.35, 0.80


class Plat:
    def __init__(self, n):
        self.n = n
        self.flops = 312e12 * n
        self.bw = 2039e9 * n
        self.ic = 300e9
        self.lat = 10e-6

    def op(self, flops, wbytes, abytes):
        return max(flops / (self.flops * EFF_C),
                   wbytes / (self.bw * EFF_M) + abytes / (self.bw * EFF_M))

    def allreduce(self, nbytes):
        if self.n <= 1:
            return 0.0
        return self.lat + 2.0 * (self.n - 1) / self.n * nbytes / self.ic


class Arch:
    def __init__(self, h, layers, heads, kv_heads, hd, vocab, moe=None, inter=None):
        self.h, self.layers, self.heads, self.kv_heads, self.hd = h, layers, heads, kv_heads, hd
        self.vocab, self.moe, self.inter = vocab, moe, inter
        self.dt = 2.0
        q = h * heads * hd
        kv = 2 * h * kv_heads * hd
        o = heads * hd * h
        self.attn_params = q + kv + o
        self.kv_bytes_tok = 2 * layers * kv_heads * hd * self.dt
        self.step_overhead = 150e-6 + layers * 40e-6

    def with_topk(self, k):
        e, _, ei, si = self.moe
        return Arch(self.h, self.layers, self.heads, self.kv_heads, self.hd,
                    self.vocab, moe=(e, k, ei, si))


TARGET = Arch(3584, 28, 28, 4, 128, 151936, moe=(64, 8, 2560, 20480))
DRAFT = Arch(896, 24, 14, 2, 64, 151936, inter=4864)
TPLAT, DPLAT = Plat(2), Plat(1)
CTX = 512  # SyntheticLm::ctx_for_pricing


def n_active(e, k, t):
    """Eq. 8: expected activated experts for t tokens through one gate."""
    return e * (1.0 - ((e - k) / e) ** t)


def fwd(arch, plat, b, tokens, ctx, budget=None):
    """forward_time_tokens_budgeted: one forward, optionally expert-capped."""
    assert b > 0 and tokens > 0
    t = float(tokens)
    dt, h, L = arch.dt, float(arch.h), float(arch.layers)
    total = plat.op(0.0, 0.0, t * h * dt) + arch.step_overhead
    attn_flops = t * (2.0 * arch.attn_params + 4.0 * arch.heads * arch.hd * ctx)
    kv_read = b * ctx * arch.kv_bytes_tok / L
    total += L * plat.op(attn_flops, arch.attn_params * dt, kv_read + 4.0 * t * h * dt)
    if arch.moe:
        E, K, ei, si = arch.moe
        total += L * (plat.op(t * 2.0 * h * E, h * E * dt, t * h * dt)
                      + plat.op(t * 6.0 * h * si, 3.0 * h * si * dt, 2.0 * t * h * dt))
        n_act = n_active(E, K, t)
        if budget is not None:
            n_act = min(n_act, float(budget))
        load = t * K / max(n_act, 1e-9)
        total += L * plat.op(n_act * load * 6.0 * h * ei,
                             n_act * 3.0 * h * ei * dt,
                             2.0 * t * K * h * dt)
    else:
        inter = arch.inter
        total += L * plat.op(t * 6.0 * h * inter, 3.0 * h * inter * dt, 2.0 * t * h * dt)
    total += L * 2.0 * plat.allreduce(t * h * dt)
    total += plat.op(t * 2.0 * h * arch.vocab, arch.vocab * h * dt, t * arch.vocab * dt)
    return total


@lru_cache(maxsize=None)
def tT(k, b, tokens, budget):
    return fwd(TARGET.with_topk(k), TPLAT, b, tokens, CTX, budget)


@lru_cache(maxsize=None)
def tD(b, tokens):
    return fwd(DRAFT, DPLAT, b, tokens, CTX)


def reject_cost(rows):
    return 40e-6 + rows * TARGET.vocab * 4.0 / TPLAT.bw


# ---------------------------------------------------------------------------
# Expected-value round model (engine/mod.rs lock-step round, uniform alpha)
# ---------------------------------------------------------------------------


def alpha_eff(alpha, k, t, budget, sens):
    """theory::budgeted_alpha at verify width t: alpha * cov**sens."""
    if budget is None:
        return alpha
    n = n_active(64, k, t)
    if budget >= n:
        return alpha
    return alpha * (budget / n) ** sens


def goodput(alpha, k, b, gamma, budget, sens):
    """Expected committed tokens per second of one steady-state round."""
    rows = b * (gamma + 1)
    a = alpha_eff(alpha, k, rows, budget, sens)
    # Expected emitted per sequence: accepted prefix + bonus token.
    emitted = sum(a ** j for j in range(gamma + 1))
    t_draft = gamma * tD(b, b) if gamma > 0 else 0.0
    t_verify = tT(k, b, rows, budget)
    t = t_draft + t_verify + reject_cost(rows)
    return b * emitted / t


def sweep(alphas, ks, batches, budgets, gammas, sens):
    print(f"{'alpha':>6} {'K':>3} {'B':>5} | {'AR tok/s':>9} | "
          f"{'best off':>9} {'g':>2} {'spd':>6} | "
          f"{'best budgeted':>13} {'g':>2} {'bud':>4} {'spd':>6} | {'ratio':>6}")
    for alpha in alphas:
        for k in ks:
            for b in batches:
                ar = goodput(alpha, k, b, 0, None, sens)
                best_off = max((goodput(alpha, k, b, g, None, sens), g)
                               for g in gammas if g > 0)
                best_bud = max((goodput(alpha, k, b, g, bud, sens), g, bud)
                               for g in gammas if g > 0
                               for bud in budgets if bud is not None)
                ratio = best_bud[0] / best_off[0]
                print(f"{alpha:>6.2f} {k:>3} {b:>5} | {ar:>9.1f} | "
                      f"{best_off[0]:>9.1f} {best_off[1]:>2} {best_off[0] / ar:>6.3f} | "
                      f"{best_bud[0]:>13.1f} {best_bud[1]:>2} {best_bud[2]:>4} "
                      f"{best_bud[0] / ar:>6.3f} | {ratio:>6.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sens", type=float, default=0.25,
                    help="acceptance-vs-budget curve exponent (bench default)")
    ap.add_argument("--full", action="store_true",
                    help="wider grid (all sensitivities, more batches)")
    args = ap.parse_args()
    budgets = [8, 16, 32, 48, 64]
    gammas = list(range(0, 9))
    if args.full:
        for sens in (0.0, 0.15, 0.25, 0.35, 0.5, 1.0):
            print(f"\n=== sensitivity {sens} ===")
            sweep([0.8, 0.9], [4, 8], [1, 2, 4, 8, 16, 32, 64, 256], budgets,
                  gammas, sens)
    else:
        print(f"=== sensitivity {args.sens} (bench grid) ===")
        sweep([0.9], [8], [4, 16, 64], budgets, gammas, args.sens)
        print("\nbit-identity spot check: budget=64 == unbudgeted, exactly")
        for (b, g) in [(4, 3), (16, 4), (64, 2)]:
            off = goodput(0.9, 8, b, g, None, args.sens)
            cap = goodput(0.9, 8, b, g, 64, args.sens)
            flag = "OK" if off == cap else "MISMATCH"
            print(f"  B={b:<3} gamma={g}: off {off:.6f} capped {cap:.6f}  {flag}")


if __name__ == "__main__":
    main()
