"""Brief training of the tiny target + draft on the synthetic corpus.

Purpose: make the models *real* — the dense 2-layer draft learns the same
structured-log distribution as the 4-layer MoE target, so serving-side
speculative decoding gets a meaningful acceptance rate (the end-to-end
example reports it). Training uses the jnp reference ops (fast under
autodiff); equivalence with the Pallas export path is pytest-verified.

Outputs (cached; rerun only if missing or --force):
  artifacts/target_weights.npz
  artifacts/draft_weights.npz
  artifacts/train_log.json
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model


def adam_init(params):
    return (
        [jnp.zeros_like(p) for p in params],
        [jnp.zeros_like(p) for p in params],
    )


def make_step(cfg, lr=3e-3, b1=0.9, b2=0.98, eps=1e-8):
    loss_grad = jax.value_and_grad(lambda p, x, y: model.train_loss(p, cfg, x, y))

    @jax.jit
    def step(params, m, v, t, x, y):
        loss, grads = loss_grad(params, x, y)
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mhat = mi / (1 - b1**t)
            vhat = vi / (1 - b2**t)
            new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        return new_p, new_m, new_v, loss

    return step


def train_model(cfg, name, steps, batch, seqlen, seed, log):
    params = model.init_params(cfg, seed)
    m, v = adam_init(params)
    step = make_step(cfg)
    data = corpus.make_corpus(6000, seed=7)
    losses = []
    t0 = time.time()
    for i, (x, y) in enumerate(corpus.batches(data, batch, seqlen, steps, seed=seed)):
        params, m, v, loss = step(params, m, v, i + 1, jnp.asarray(x), jnp.asarray(y))
        if i % 25 == 0 or i == steps - 1:
            losses.append(float(loss))
            print(f"[{name}] step {i:4d} loss {float(loss):.4f}", flush=True)
    log[name] = {
        "steps": steps,
        "losses": losses,
        "seconds": round(time.time() - t0, 1),
    }
    assert losses[-1] < losses[0] * 0.7, f"{name} failed to learn: {losses}"
    return params


def save_params(path, cfg, params):
    arrays = {
        name: np.asarray(p)
        for (name, _), p in zip(model.param_specs(cfg), params)
    }
    np.savez(path, **arrays)


def load_params(path, cfg):
    data = np.load(path)
    return [jnp.asarray(data[name]) for name, _ in model.param_specs(cfg)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seqlen", type=int, default=64)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    target_path = os.path.join(args.out_dir, "target_weights.npz")
    draft_path = os.path.join(args.out_dir, "draft_weights.npz")
    if not args.force and os.path.exists(target_path) and os.path.exists(draft_path):
        print("weights exist; skipping training (use --force to retrain)")
        return

    log = {}
    target = train_model(
        model.target_config(), "target", args.steps, args.batch, args.seqlen, 1, log
    )
    draft = train_model(
        model.draft_config(), "draft", args.steps, args.batch, args.seqlen, 2, log
    )
    save_params(target_path, model.target_config(), target)
    save_params(draft_path, model.draft_config(), draft)
    with open(os.path.join(args.out_dir, "train_log.json"), "w") as f:
        json.dump(log, f, indent=2)
    print(f"saved weights to {args.out_dir}")


if __name__ == "__main__":
    main()
