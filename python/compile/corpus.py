"""Synthetic training corpus for the tiny real model.

The paper evaluates on code (HumanEval) and conversation (MT-Bench); the
relevant property for speculative decoding is *predictability* — code-like
text has structure a small draft model can learn. This corpus generates
templated "service log" lines: highly regular (so the 2-layer dense draft
reaches a useful acceptance rate against the 4-layer MoE target) but with
enough variation that the models must actually learn.

Byte-level tokens (ids = byte values); ids 0 (EOS) and 1 (BOS) are reserved
and never appear in content (ASCII only). Must agree with
rust/src/tokenizer/mod.rs.
"""

import numpy as np

BOS = 1
EOS = 0
VOCAB = 256

_METHODS = ["GET", "PUT", "POST", "HEAD"]
_PATHS = ["/api/v1/users", "/api/v1/items", "/metrics", "/health", "/api/v2/orders"]
_STATUS = ["200 OK", "201 CREATED", "404 NOT_FOUND", "500 ERROR"]
_LEVELS = ["INFO", "WARN", "DEBUG"]


def make_line(rng: np.random.Generator) -> str:
    """One structured log line."""
    kind = rng.integers(0, 3)
    if kind == 0:
        return "{} {} {} {} in {}ms".format(
            _LEVELS[rng.integers(0, len(_LEVELS))],
            _METHODS[rng.integers(0, len(_METHODS))],
            _PATHS[rng.integers(0, len(_PATHS))],
            _STATUS[rng.integers(0, len(_STATUS))],
            rng.integers(1, 500),
        )
    if kind == 1:
        return "INFO worker={} queue={} batch={} tokens={}".format(
            rng.integers(0, 8),
            rng.integers(0, 64),
            rng.integers(1, 33),
            rng.integers(1, 2048),
        )
    return "DEBUG expert[{}] load={} activated={} total={}".format(
        rng.integers(0, 8),
        rng.integers(0, 100),
        rng.integers(1, 9),
        rng.integers(1, 65),
    )


def make_corpus(n_lines: int, seed: int = 0) -> np.ndarray:
    """Token stream: BOS line EOS BOS line EOS ..."""
    rng = np.random.default_rng(seed)
    toks = []
    for _ in range(n_lines):
        toks.append(BOS)
        toks.extend(make_line(rng).encode("ascii"))
        toks.append(EOS)
    return np.array(toks, dtype=np.int32)


def batches(corpus: np.ndarray, batch: int, seqlen: int, steps: int, seed: int = 0):
    """Yield (inputs, targets) next-token training batches."""
    rng = np.random.default_rng(seed + 1)
    n = len(corpus) - seqlen - 1
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        x = np.stack([corpus[s : s + seqlen] for s in starts])
        y = np.stack([corpus[s + 1 : s + seqlen + 1] for s in starts])
        yield x, y


def sample_prompts(n: int, min_len: int = 8, seed: int = 123) -> list:
    """Prompt prefixes for serving demos: the first `min_len`+ bytes of a
    fresh line, BOS-prefixed (what rust's tokenizer::encode produces)."""
    rng = np.random.default_rng(seed)
    prompts = []
    for _ in range(n):
        line = make_line(rng).encode("ascii")
        cut = max(min_len, len(line) // 2)
        prompts.append([BOS] + list(line[:cut]))
    return prompts
