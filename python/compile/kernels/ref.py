"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These are the L1 reference implementations: simple, obviously-correct jnp
code. pytest/hypothesis sweeps assert the Pallas kernels match these to
float tolerance; the training loop also uses them (interpret-mode Pallas
would be needlessly slow under autodiff).
"""

import jax
import jax.numpy as jnp


def moe_ffn_ref(x, w1, w2, route_w):
    """Routed MoE FFN, dense-einsum reference.

    Args:
      x:       [T, D]   token activations.
      w1:      [E, D, F] expert up-projections.
      w2:      [E, F, D] expert down-projections.
      route_w: [T, E]   routing weights (0 for inactive experts; already
               softmax-normalized over the top-K selection).

    Returns: [T, D].
    """
    # h[e, t, f] = relu(x @ w1[e])
    h = jnp.maximum(jnp.einsum("td,edf->etf", x, w1), 0.0)
    # y[e, t, d] = h @ w2[e]
    y = jnp.einsum("etf,efd->etd", h, w2)
    # combine: sum_e route_w[t, e] * y[e, t, :]
    return jnp.einsum("te,etd->td", route_w, y)


def dense_ffn_ref(x, w1, w2):
    """Plain dense FFN: relu(x @ w1) @ w2. x: [T, D], w1: [D, F], w2: [F, D]."""
    return jnp.maximum(x @ w1, 0.0) @ w2


def decode_attention_ref(q, k_cache, v_cache, q_pos):
    """Decode attention over a padded KV cache.

    Args:
      q:       [B, S, H, Dh] new-token queries.
      k_cache: [B, Smax, H, Dh] keys (garbage beyond each seq's length).
      v_cache: [B, Smax, H, Dh] values.
      q_pos:   [B, S] absolute position of each query token (the cache is
               assumed to already hold the new tokens at those positions).

    Causal rule: the query at absolute position p attends to cache
    positions j <= p. Returns [B, S, H, Dh].
    """
    scale = (1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))).astype(q.dtype)
    scores = jnp.einsum("bshd,bjhd->bhsj", q, k_cache) * scale
    smax = k_cache.shape[1]
    j = jnp.arange(smax)[None, None, :]  # [1, 1, Smax]
    allowed = j <= q_pos[:, :, None]  # [B, S, Smax]
    scores = jnp.where(allowed[:, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhsj,bjhd->bshd", probs, v_cache)
