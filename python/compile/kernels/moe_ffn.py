"""L1 Pallas kernel: the routed MoE FFN — the paper's compute hot spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's GPUs run
the MoE FFN as a CUTLASS grouped GEMM where each threadblock keeps one
expert's weight tile in SMEM. On TPU the scarce fast memory is VMEM, so the
Pallas grid iterates over *experts* — each grid step holds exactly one
expert's W1/W2 resident (the BlockSpec index maps select expert `e`) while
the token block streams through the MXU. This expresses the paper's core
quantity directly: per-parameter-load token work = T̄_exp (Eq. 10); when
few tokens route to an expert the step is memory-bound, which is the entire
§3.2 argument.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO for both pytest and the
Rust runtime. Real-TPU tiling estimates are documented in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_kernel(x_ref, w1_ref, w2_ref, rw_ref, o_ref):
    """One grid step = one expert.

    Block shapes (leading expert axis squeezed by the BlockSpec):
      x_ref:  [T, D]  — all tokens (tiny model: whole batch fits in VMEM)
      w1_ref: [D, F]  — this expert's up-projection
      w2_ref: [F, D]  — this expert's down-projection
      rw_ref: [T, 1]  — this expert's routing weight per token
      o_ref:  [T, D]  — accumulated output
    """
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    h = jnp.maximum(jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32), 0.0)
    y = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    # Weighted combine; tokens not routed to this expert have weight 0, so
    # their contribution vanishes (compute is wasted for them — exactly the
    # "expert loaded but under-utilized" regime the paper analyzes).
    o_ref[...] += rw_ref[...] * y


@functools.partial(jax.jit, static_argnames=())
def moe_ffn(x, w1, w2, route_w):
    """Pallas routed MoE FFN. Shapes as in ref.moe_ffn_ref."""
    t, d = x.shape
    e, _, f = w1.shape
    assert w2.shape == (e, f, d), (w2.shape, (e, f, d))
    assert route_w.shape == (t, e)
    grid = (e,)
    return pl.pallas_call(
        _moe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, d), lambda e_: (0, 0)),  # x: full, every step
            pl.BlockSpec((None, d, f), lambda e_: (e_, 0, 0)),  # w1[e]
            pl.BlockSpec((None, f, d), lambda e_: (e_, 0, 0)),  # w2[e]
            pl.BlockSpec((t, 1), lambda e_: (0, e_)),  # route_w[:, e]
        ],
        out_specs=pl.BlockSpec((t, d), lambda e_: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, w1, w2, route_w)


def _moe_kernel_blocked(x_ref, w1_ref, w2_ref, rw_ref, o_ref, *, tile_t):
    """Token-tiled variant: grid (E, ceil(T/tile_t)). Each step computes one
    (expert, token-tile) pair — the shape a real-TPU schedule would use to
    bound VMEM by tile_t·D + D·F + F·D."""
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    h = jnp.maximum(jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32), 0.0)
    y = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += rw_ref[...] * y


def moe_ffn_blocked(x, w1, w2, route_w, tile_t=8):
    """Token-tiled Pallas MoE FFN (used by the kernel test sweep to check
    the tiled schedule agrees with the monolithic one)."""
    t, d = x.shape
    e, _, f = w1.shape
    tile_t = min(tile_t, t)
    assert t % tile_t == 0, "token count must divide the tile for this variant"
    grid = (e, t // tile_t)
    kernel = functools.partial(_moe_kernel_blocked, tile_t=tile_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, d), lambda e_, i: (i, 0)),
            pl.BlockSpec((None, d, f), lambda e_, i: (e_, 0, 0)),
            pl.BlockSpec((None, f, d), lambda e_, i: (e_, 0, 0)),
            pl.BlockSpec((tile_t, 1), lambda e_, i: (i, e_)),
        ],
        out_specs=pl.BlockSpec((tile_t, d), lambda e_, i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, w1, w2, route_w)


def vmem_bytes_per_step(t, d, f, dtype_bytes=4):
    """Analytic VMEM footprint of one grid step of `moe_ffn` (DESIGN.md
    §Perf): token block + one expert's W1/W2 + routing column + output."""
    return dtype_bytes * (t * d + d * f + f * d + t + t * d)
