"""L1 Pallas kernel: decode attention over a padded KV cache.

Grid iterates over (batch, head): each step loads one sequence's KV slab
for one head into VMEM and computes all S query positions against it —
the decode-side analogue of a flash-attention threadblock, re-expressed
as a BlockSpec HBM→VMEM schedule. `interpret=True` (see moe_ffn.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
    """Block shapes (one batch element b, one head h per grid step):
    q_ref:   [S, Dh]
    k_ref:   [Smax, Dh]
    v_ref:   [Smax, Dh]
    pos_ref: [S]      absolute positions of the queries
    o_ref:   [S, Dh]
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    pos = pos_ref[...]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [S, Smax]
    j = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = j <= pos[:, None]
    scores = jnp.where(mask, scores, -1e30)
    # Numerically-stable softmax.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def decode_attention(q, k_cache, v_cache, q_pos):
    """Pallas decode attention. Shapes as in ref.decode_attention_ref:
    q [B,S,H,Dh], k_cache/v_cache [B,Smax,H,Dh], q_pos [B,S] (int32).
    Returns [B,S,H,Dh].
    """
    b, s, h, dh = q.shape
    smax = k_cache.shape[1]
    assert k_cache.shape == (b, smax, h, dh)
    assert v_cache.shape == (b, smax, h, dh)
    assert q_pos.shape == (b, s)
    grid = (b, h)
    return pl.pallas_call(
        _attn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, s, None, dh), lambda b_, h_: (b_, 0, h_, 0)),
            pl.BlockSpec((None, smax, None, dh), lambda b_, h_: (b_, 0, h_, 0)),
            pl.BlockSpec((None, smax, None, dh), lambda b_, h_: (b_, 0, h_, 0)),
            pl.BlockSpec((None, s), lambda b_, h_: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((None, s, None, dh), lambda b_, h_: (b_, 0, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32),
        interpret=True,
    )(q, k_cache, v_cache, q_pos)
