"""AOT export: lower the L2 models to HLO text + pack weights for Rust.

Interchange contract with `rust/src/runtime/`:

- **HLO text** (not serialized protos — xla_extension 0.5.1 rejects jax≥0.5
  64-bit instruction ids; the text parser reassigns ids). One file per
  (model, batch-bucket B, step-size S):  `{model}_b{B}_s{S}.hlo.txt`.
  Signature: params... , tokens[B,S] i32, k[L,B,Smax,H,Dh] f32,
  v[...] f32, lens[B] i32  →  tuple(logits[B,S,V], new_k, new_v).
- **weights.bin**: magic `MOESDW01`, then per tensor: u32 name_len, name,
  u32 ndim, u32 dims…, f32 raw data (little-endian), in `param_specs`
  order, target model first then draft.
- **manifest.json**: configs, bucket/step lists, artifact names, parameter
  table, and a numerics test vector (tokens + expected logits slice) the
  Rust integration test replays through PJRT.

Step sizes: S ∈ {1..γ_max+1} covers AR decode (S=1) and SD verify
(S=γ+1 ≤ 5); S=PREFILL covers padded prompt ingestion. The draft only
proposes token-by-token (plus a ≤2-token backlog), so it gets S ∈ {1,2}.
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .train import load_params

BUCKETS = [1, 2, 4, 8]
TARGET_STEPS = [1, 2, 3, 4, 5]
DRAFT_STEPS = [1, 2]
PREFILL_S = 32
GAMMA_MAX = 4


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg, b, s):
    """Lower one (B, S) forward variant to HLO text."""

    def fn(params, tokens, k_cache, v_cache, lens):
        return model.forward(params, cfg, tokens, k_cache, v_cache, lens, use_pallas=True)

    kv_shape = (cfg["layers"], b, cfg["kv_max"], cfg["heads"], cfg["head_dim"])
    specs = (
        [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in model.param_specs(cfg)],
        jax.ShapeDtypeStruct((b, s), jnp.int32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def write_weights_bin(path, models):
    """models: list of (prefix, cfg, params)."""
    with open(path, "wb") as f:
        f.write(b"MOESDW01")
        total = sum(len(model.param_specs(cfg)) for _, cfg, _ in models)
        f.write(struct.pack("<I", total))
        for prefix, cfg, params in models:
            for (name, shape), p in zip(model.param_specs(cfg), params):
                arr = np.asarray(p, dtype=np.float32)
                assert arr.shape == tuple(shape), (name, arr.shape, shape)
                full = f"{prefix}.{name}".encode()
                f.write(struct.pack("<I", len(full)))
                f.write(full)
                f.write(struct.pack("<I", arr.ndim))
                for dim in arr.shape:
                    f.write(struct.pack("<I", dim))
                f.write(arr.astype("<f4").tobytes())


def numerics_vector(cfg, params):
    """A replayable test case: fixed tokens through the pallas path."""
    b, s = 1, 2
    tokens = jnp.asarray([[65, 66]], jnp.int32)
    k0, v0 = model.empty_cache(cfg, b)
    lens = jnp.zeros((b,), jnp.int32)
    logits, _, _ = model.forward(params, cfg, tokens, k0, v0, lens, use_pallas=True)
    return {
        "tokens": [65, 66],
        "logits_row0_first8": [float(x) for x in np.asarray(logits)[0, 0, :8]],
        "logits_row1_first8": [float(x) for x in np.asarray(logits)[0, 1, :8]],
        "argmax_row1": int(np.asarray(logits)[0, 1].argmax()),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    tcfg, dcfg = model.target_config(), model.draft_config()
    target = load_params(os.path.join(args.out_dir, "target_weights.npz"), tcfg)
    draft = load_params(os.path.join(args.out_dir, "draft_weights.npz"), dcfg)

    artifacts = {}
    jobs = []
    for b in BUCKETS:
        for s in TARGET_STEPS + [PREFILL_S]:
            jobs.append(("target", tcfg, b, s))
        for s in DRAFT_STEPS + [PREFILL_S]:
            jobs.append(("draft", dcfg, b, s))
    for name, cfg, b, s in jobs:
        key = f"{name}_b{b}_s{s}"
        fname = f"{key}.hlo.txt"
        text = lower_variant(cfg, b, s)
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        artifacts[key] = fname
        print(f"lowered {key}: {len(text)} chars", flush=True)

    write_weights_bin(
        os.path.join(args.out_dir, "weights.bin"),
        [("target", tcfg, target), ("draft", dcfg, draft)],
    )

    def cfg_json(cfg):
        return {k: v for k, v in cfg.items()}

    def param_table(prefix, cfg):
        return [
            {"name": f"{prefix}.{name}", "shape": list(shape)}
            for name, shape in model.param_specs(cfg)
        ]

    manifest = {
        "format": 1,
        "buckets": BUCKETS,
        "target_steps": TARGET_STEPS,
        "draft_steps": DRAFT_STEPS,
        "prefill_s": PREFILL_S,
        "gamma_max": GAMMA_MAX,
        "target": cfg_json(tcfg),
        "draft": cfg_json(dcfg),
        "artifacts": artifacts,
        "params": param_table("target", tcfg) + param_table("draft", dcfg),
        "numerics": {
            "target": numerics_vector(tcfg, target),
            "draft": numerics_vector(dcfg, draft),
        },
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(artifacts)} artifacts")


if __name__ == "__main__":
    main()
