"""L2: the JAX models — `MoesdNet` (tiny MoE target) and a dense draft.

Dims must agree with `rust/src/arch/presets.rs::{moesd_tiny, moesd_tiny_draft}`:

  target: hidden 128, layers 4, heads 4 (head_dim 32), vocab 256,
          MoE FFN: E=8 experts, top-2, expert_inter 256, no shared expert.
  draft:  hidden 128, layers 2, dense FFN inter 256.

The forward function is *the* serving step: it consumes `S` new tokens per
sequence against an explicit padded KV cache and returns logits for every
new position plus the updated cache. Prefill, AR decode and SD verify are
all the same function at different `S` — which is exactly what makes the
T_T(B, s) accounting of the paper well-defined on the real system.

Parameters are a flat *list* of arrays in a fixed documented order
(`param_specs`), so the AOT artifacts and the Rust weight loader agree
without pytree metadata. `use_pallas=True` routes the MoE FFN and
attention through the L1 Pallas kernels (the export path); `False` uses
the jnp references (the training path). Both are verified equal in tests.
"""

import math
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as attn_k
from .kernels import moe_ffn as moe_k
from .kernels import ref

# ---- configuration ---------------------------------------------------------

VOCAB = 256
HIDDEN = 128
HEADS = 4
HEAD_DIM = 32
KV_MAX = 160  # padded KV length; prompts ≤ 32, generation ≤ 96

TARGET_LAYERS = 4
TARGET_EXPERTS = 8
TARGET_TOPK = 2
TARGET_INTER = 256

DRAFT_LAYERS = 2
DRAFT_INTER = 256

ROPE_BASE = 10000.0


def target_config():
    return dict(
        vocab=VOCAB,
        hidden=HIDDEN,
        heads=HEADS,
        head_dim=HEAD_DIM,
        layers=TARGET_LAYERS,
        experts=TARGET_EXPERTS,
        topk=TARGET_TOPK,
        inter=TARGET_INTER,
        kv_max=KV_MAX,
        moe=True,
    )


def draft_config():
    return dict(
        vocab=VOCAB,
        hidden=HIDDEN,
        heads=HEADS,
        head_dim=HEAD_DIM,
        layers=DRAFT_LAYERS,
        experts=0,
        topk=0,
        inter=DRAFT_INTER,
        kv_max=KV_MAX,
        moe=False,
    )


def param_specs(cfg) -> List[tuple]:
    """(name, shape) list in the exact flat order used everywhere."""
    d, h = cfg["hidden"], cfg["heads"] * cfg["head_dim"]
    specs = [("embed", (cfg["vocab"], d))]
    for i in range(cfg["layers"]):
        specs += [
            (f"l{i}.ln1", (d,)),
            (f"l{i}.wq", (d, h)),
            (f"l{i}.wk", (d, h)),
            (f"l{i}.wv", (d, h)),
            (f"l{i}.wo", (h, d)),
            (f"l{i}.ln2", (d,)),
        ]
        if cfg["moe"]:
            specs += [
                (f"l{i}.gate", (d, cfg["experts"])),
                (f"l{i}.w1", (cfg["experts"], d, cfg["inter"])),
                (f"l{i}.w2", (cfg["experts"], cfg["inter"], d)),
            ]
        else:
            specs += [
                (f"l{i}.w1", (d, cfg["inter"])),
                (f"l{i}.w2", (cfg["inter"], d)),
            ]
    specs.append(("ln_f", (d,)))
    return specs


def init_params(cfg, seed: int) -> List[jnp.ndarray]:
    """He-style initialization in the flat param order."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_specs(cfg):
        if name.endswith((".ln1", ".ln2")) or name == "ln_f":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params.append(
                jnp.asarray(rng.normal(0.0, std, size=shape), jnp.float32)
            )
    return params


# ---- building blocks --------------------------------------------------------


def rms_norm(x, w):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * w


def rope(x, pos):
    """Rotary embedding. x: [B, S, H, Dh], pos: [B, S] absolute positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = ROPE_BASE ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[:, :, None].astype(jnp.float32) * freqs[None, None, :]  # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]  # [B,S,1,half]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def top_k_route(gate_logits, topk):
    """Routing weights [T, E]: softmax over the per-token top-K logits,
    zero elsewhere. Differentiable w.r.t. the selected logits (standard
    top-k gating).

    Implemented as K iterative argmax passes rather than `jax.lax.top_k`:
    jax ≥0.5 lowers top_k to the `topk(..., largest=true)` HLO op, which
    the xla_extension 0.5.1 text parser used by the Rust runtime rejects.
    argmax + one_hot lower to plain reduce/iota/select ops that round-trip
    cleanly (same selection semantics; ties break toward the lower index
    in both formulations).
    """
    _, e = gate_logits.shape
    masked = gate_logits
    onehots = []
    for _ in range(topk):
        idx = jnp.argmax(masked, axis=-1)  # [T]
        oh = jax.nn.one_hot(idx, e, dtype=gate_logits.dtype)  # [T, E]
        onehots.append(oh)
        masked = jnp.where(oh > 0, -1e30, masked)
    sel = jnp.stack(onehots, axis=1)  # [T, K, E]
    vals = jnp.einsum("tke,te->tk", sel, gate_logits)  # [T, K]
    w = jax.nn.softmax(vals, axis=-1)
    return jnp.einsum("tk,tke->te", w, sel)


# ---- the forward step --------------------------------------------------------


def forward(params, cfg, tokens, k_cache, v_cache, lens, use_pallas):
    """Process S new tokens per sequence.

    Args:
      params:  flat list per `param_specs(cfg)`.
      tokens:  [B, S] int32 new tokens.
      k_cache: [L, B, Smax, H, Dh] keys (updated copy returned).
      v_cache: [L, B, Smax, H, Dh] values.
      lens:    [B] int32 context lengths before these tokens.
      use_pallas: route hot ops through the L1 kernels.

    Returns (logits [B, S, V], new_k, new_v). New tokens are written at
    positions lens..lens+S-1; positions ≥ lens+S keep stale data that the
    causal mask makes unreadable.
    """
    b, s = tokens.shape
    d = cfg["hidden"]
    heads, dh = cfg["heads"], cfg["head_dim"]
    it = iter(params)
    nxt = lambda: next(it)

    embed = nxt()
    x = embed[tokens]  # [B, S, D]
    pos = lens[:, None] + jnp.arange(s)[None, :]  # [B, S]

    new_k, new_v = [], []
    for li in range(cfg["layers"]):
        ln1 = nxt()
        wq, wk, wv, wo = nxt(), nxt(), nxt(), nxt()
        ln2 = nxt()

        h = rms_norm(x, ln1)
        q = (h @ wq).reshape(b, s, heads, dh)
        k = (h @ wk).reshape(b, s, heads, dh)
        v = (h @ wv).reshape(b, s, heads, dh)
        q = rope(q, pos)
        k = rope(k, pos)

        # Scatter new K/V into the cache at per-sequence offsets.
        def scatter(cache, new):
            def one(c, n, off):
                return jax.lax.dynamic_update_slice(c, n, (off, 0, 0))

            return jax.vmap(one)(cache, new, lens)

        kc = scatter(k_cache[li], k)
        vc = scatter(v_cache[li], v)
        new_k.append(kc)
        new_v.append(vc)

        if use_pallas:
            attn = attn_k.decode_attention(q, kc, vc, pos)
        else:
            attn = ref.decode_attention_ref(q, kc, vc, pos)
        x = x + attn.reshape(b, s, heads * dh) @ wo

        h2 = rms_norm(x, ln2)
        flat = h2.reshape(b * s, d)
        if cfg["moe"]:
            gate, w1, w2 = nxt(), nxt(), nxt()
            route = top_k_route(flat @ gate, cfg["topk"])
            if use_pallas:
                y = moe_k.moe_ffn(flat, w1, w2, route)
            else:
                y = ref.moe_ffn_ref(flat, w1, w2, route)
        else:
            w1, w2 = nxt(), nxt()
            y = ref.dense_ffn_ref(flat, w1, w2)
        x = x + y.reshape(b, s, d)

    ln_f = nxt()
    x = rms_norm(x, ln_f)
    logits = x @ embed.T  # tied embeddings
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def empty_cache(cfg, batch):
    shape = (cfg["layers"], batch, cfg["kv_max"], cfg["heads"], cfg["head_dim"])
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


# ---- training-side helpers ---------------------------------------------------


def train_loss(params, cfg, x, y):
    """Next-token cross-entropy over a [B, S] batch (no cache reuse —
    training always starts at position 0)."""
    b, s = x.shape
    k0, v0 = empty_cache(cfg, b)
    lens = jnp.zeros((b,), jnp.int32)
    logits, _, _ = forward(params, cfg, x, k0, v0, lens, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[:, :, None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def expert_activation_counts(params, cfg, tokens, lens, k_cache, v_cache):
    """Instrumentation for Fig. 1-style measurements on the real model:
    number of distinct experts activated in layer 0 for this batch."""
    it = iter(params)
    embed = next(it)
    x = embed[tokens]
    ln1 = next(it)
    for _ in range(4):
        next(it)  # wq wk wv wo
    ln2 = next(it)
    gate = next(it)
    del k_cache, v_cache
    h2 = rms_norm(x, ln2)  # layer-0 pre-FFN (attention skipped: gate stats only)
    flat = h2.reshape(-1, cfg["hidden"])
    route = top_k_route(flat @ gate, cfg["topk"])
    return (route.sum(axis=0) > 0).sum()
