# pytest: AOT artifact contract — manifest consistency, weights.bin
# binary format, and HLO text properties the Rust loader depends on.
import json
import struct
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"

needs_artifacts = pytest.mark.skipif(
    not (ARTIFACTS / "manifest.json").exists(),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_is_complete():
    m = json.loads((ARTIFACTS / "manifest.json").read_text())
    assert m["format"] == 1
    assert m["buckets"] == aot.BUCKETS
    # Every advertised artifact file exists and is parseable-looking HLO.
    for key, fname in m["artifacts"].items():
        path = ARTIFACTS / fname
        assert path.exists(), f"missing {fname}"
        head = path.read_text()[:200]
        assert "HloModule" in head, f"{fname} does not look like HLO text"
    # All (bucket, step) combinations are present.
    for b in m["buckets"]:
        for s in m["target_steps"] + [m["prefill_s"]]:
            assert f"target_b{b}_s{s}" in m["artifacts"]
        for s in m["draft_steps"] + [m["prefill_s"]]:
            assert f"draft_b{b}_s{s}" in m["artifacts"]


@needs_artifacts
def test_weights_bin_roundtrip():
    m = json.loads((ARTIFACTS / "manifest.json").read_text())
    blob = (ARTIFACTS / "weights.bin").read_bytes()
    assert blob[:8] == b"MOESDW01"
    (count,) = struct.unpack_from("<I", blob, 8)
    assert count == len(m["params"])
    off = 12
    for entry in m["params"]:
        (nlen,) = struct.unpack_from("<I", blob, off)
        off += 4
        name = blob[off : off + nlen].decode()
        off += nlen
        assert name == entry["name"]
        (ndim,) = struct.unpack_from("<I", blob, off)
        off += 4
        dims = struct.unpack_from(f"<{ndim}I", blob, off)
        off += 4 * ndim
        assert list(dims) == entry["shape"], name
        n = int(np.prod(dims))
        vals = np.frombuffer(blob, dtype="<f4", count=n, offset=off)
        assert np.isfinite(vals).all(), f"{name} has non-finite weights"
        off += 4 * n
    assert off == len(blob), "trailing bytes in weights.bin"


@needs_artifacts
def test_numerics_vector_replays():
    """The manifest's expected logits must match a fresh forward through
    the pallas path with the saved weights — this is the same check the
    Rust integration test performs through PJRT."""
    from compile.train import load_params

    m = json.loads((ARTIFACTS / "manifest.json").read_text())
    cfg = model.target_config()
    params = load_params(str(ARTIFACTS / "target_weights.npz"), cfg)
    vec = m["numerics"]["target"]
    got = aot.numerics_vector(cfg, params)
    np.testing.assert_allclose(
        got["logits_row1_first8"], vec["logits_row1_first8"], rtol=1e-5
    )
    assert got["argmax_row1"] == vec["argmax_row1"]


@needs_artifacts
def test_hlo_has_expected_parameter_count():
    """Target HLO entry takes |params| + tokens + k + v + lens arguments."""
    m = json.loads((ARTIFACTS / "manifest.json").read_text())
    n_target_params = sum(1 for p in m["params"] if p["name"].startswith("target."))
    text = (ARTIFACTS / m["artifacts"]["target_b1_s1"]).read_text()
    # Parse the ENTRY computation body (up to its closing brace) and count
    # distinct parameter indices.
    entry = text[text.index("ENTRY") :]
    body = entry[: entry.index("\n}")]
    import re

    indices = {int(i) for i in re.findall(r"parameter\((\d+)\)", body)}
    assert len(indices) == n_target_params + 4, (len(indices), n_target_params)


def test_lower_variant_smoke():
    """Lowering works from a clean state (no artifacts needed)."""
    text = aot.lower_variant(model.draft_config(), b=1, s=1)
    assert "HloModule" in text
    assert "mosaic" not in text.lower()
