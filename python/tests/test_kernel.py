# pytest: Pallas kernels vs pure-jnp oracles — the CORE L1 correctness
# signal. hypothesis sweeps shapes and routing configurations.
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import moe_ffn as moe_k
from compile.kernels import ref


def make_route(rng, t, e, k):
    """Top-k softmax routing weights like the model's gate produces."""
    logits = rng.normal(size=(t, e))
    route = np.zeros((t, e), np.float32)
    for i in range(t):
        idx = np.argsort(-logits[i])[:k]
        w = np.exp(logits[i][idx])
        route[i][idx] = w / w.sum()
    return jnp.asarray(route)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 12),
    d=st.sampled_from([4, 8, 16]),
    f=st.sampled_from([4, 16, 32]),
    e=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_moe_ffn_matches_ref(t, d, f, e, seed, data):
    k = data.draw(st.integers(1, e))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(e, f, d)) * 0.2, jnp.float32)
    route = make_route(rng, t, e, k)
    got = moe_k.moe_ffn(x, w1, w2, route)
    want = ref.moe_ffn_ref(x, w1, w2, route)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    tiles=st.sampled_from([(8, 2), (8, 4), (12, 3), (16, 8)]),
    e=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_moe_ffn_blocked_matches_monolithic(tiles, e, seed):
    t, tile = tiles
    d, f, k = 8, 16, min(2, e)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(e, f, d)) * 0.2, jnp.float32)
    route = make_route(rng, t, e, k)
    mono = moe_k.moe_ffn(x, w1, w2, route)
    tiled = moe_k.moe_ffn_blocked(x, w1, w2, route, tile_t=tile)
    np.testing.assert_allclose(np.asarray(mono), np.asarray(tiled), rtol=1e-4, atol=1e-4)


def test_moe_ffn_zero_route_gives_zero():
    x = jnp.ones((3, 4), jnp.float32)
    w1 = jnp.ones((2, 4, 8), jnp.float32)
    w2 = jnp.ones((2, 8, 4), jnp.float32)
    route = jnp.zeros((3, 2), jnp.float32)
    out = moe_k.moe_ffn(x, w1, w2, route)
    np.testing.assert_allclose(np.asarray(out), 0.0)


def test_moe_ffn_single_expert_equals_dense():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(1, 8, 16)) * 0.3, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(1, 16, 8)) * 0.3, jnp.float32)
    route = jnp.ones((5, 1), jnp.float32)
    out = moe_k.moe_ffn(x, w1, w2, route)
    dense = ref.dense_ffn_ref(x, w1[0], w2[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    s=st.integers(1, 5),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([4, 8]),
    smax=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(b, s, h, dh, smax, seed):
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, smax - s, size=b)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, smax, h, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, smax, h, dh)), jnp.float32)
    qpos = jnp.asarray(lens[:, None] + np.arange(s)[None, :], jnp.int32)
    got = attn_k.decode_attention(q, kc, vc, qpos)
    want = ref.decode_attention_ref(q, kc, vc, qpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_attention_is_causal():
    """Changing KV content beyond the query position must not change output."""
    rng = np.random.default_rng(1)
    b, s, h, dh, smax = 1, 1, 2, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    kc = np.asarray(rng.normal(size=(b, smax, h, dh)), np.float32)
    vc = np.asarray(rng.normal(size=(b, smax, h, dh)), np.float32)
    qpos = jnp.asarray([[5]], jnp.int32)
    out1 = attn_k.decode_attention(q, jnp.asarray(kc), jnp.asarray(vc), qpos)
    kc[0, 6:] = 99.0  # poison the future
    vc[0, 6:] = -99.0
    out2 = attn_k.decode_attention(q, jnp.asarray(kc), jnp.asarray(vc), qpos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_vmem_estimate_sane():
    # One expert of the tiny model in f32 plus an 8-token tile fits in a
    # 16 MiB TPU VMEM budget with huge margin (DESIGN.md §Perf).
    bytes_ = moe_k.vmem_bytes_per_step(t=8, d=128, f=256)
    assert bytes_ < 16 * 1024 * 1024
    assert bytes_ > 128 * 256 * 4  # at least one weight matrix


def test_kernels_lower_to_hlo_without_custom_calls():
    """interpret=True must produce plain HLO the CPU PJRT client can run —
    no Mosaic custom-calls (the gotcha in /opt/xla-example/README.md)."""
    t, d, f, e = 4, 8, 16, 2
    x = jnp.ones((t, d), jnp.float32)
    w1 = jnp.ones((e, d, f), jnp.float32)
    w2 = jnp.ones((e, f, d), jnp.float32)
    route = jnp.ones((t, e), jnp.float32) / e
    lowered = jax.jit(moe_k.moe_ffn).lower(x, w1, w2, route)
    text = lowered.compiler_ir("stablehlo")
    assert "mosaic" not in str(text).lower()
