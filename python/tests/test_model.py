# pytest: L2 model semantics — KV-cache incrementality, pallas/ref
# equivalence, routing statistics, and training smoke.
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus, model


@pytest.fixture(scope="module")
def tparams():
    return model.init_params(model.target_config(), 3)


@pytest.fixture(scope="module")
def dparams():
    return model.init_params(model.draft_config(), 4)


def fwd(params, cfg, tokens, k, v, lens, use_pallas=False):
    return model.forward(params, cfg, jnp.asarray(tokens, jnp.int32), k, v,
                         jnp.asarray(lens, jnp.int32), use_pallas)


def test_forward_shapes(tparams):
    cfg = model.target_config()
    b, s = 2, 3
    k0, v0 = model.empty_cache(cfg, b)
    logits, k1, v1 = fwd(tparams, cfg, [[65, 66, 67], [70, 71, 72]], k0, v0, [0, 0])
    assert logits.shape == (b, s, cfg["vocab"])
    assert k1.shape == k0.shape and v1.shape == v0.shape


@settings(max_examples=8, deadline=None)
@given(split=st.integers(1, 4), seed=st.integers(0, 1000))
def test_incremental_equals_full(split, seed):
    """Processing s tokens in two chunks equals one pass — the property the
    SD verify step depends on."""
    cfg = model.target_config()
    params = model.init_params(cfg, 5)
    rng = np.random.default_rng(seed)
    s = 5
    toks = rng.integers(2, 256, size=(1, s))
    k0, v0 = model.empty_cache(cfg, 1)
    full, _, _ = fwd(params, cfg, toks, k0, v0, [0])
    la, ka, va = fwd(params, cfg, toks[:, :split], k0, v0, [0])
    lb, _, _ = fwd(params, cfg, toks[:, split:], ka, va, [split])
    np.testing.assert_allclose(
        np.asarray(full[:, split:]), np.asarray(lb), rtol=3e-4, atol=3e-4
    )


def test_rollback_by_lens_is_exact(tparams):
    """SD rollback: recompute with a shorter `lens` after garbage was
    written beyond it — results must match a clean cache. This is the
    property that lets Rust roll back by just decrementing lens."""
    cfg = model.target_config()
    rng = np.random.default_rng(0)
    toks = rng.integers(2, 256, size=(1, 4))
    k0, v0 = model.empty_cache(cfg, 1)
    # Commit 2 tokens, then speculatively run 2 more (garbage at pos 2,3).
    _, k2, v2 = fwd(tparams, cfg, toks[:, :2], k0, v0, [0])
    _, kdirty, vdirty = fwd(tparams, cfg, toks[:, 2:], k2, v2, [2])
    # "Reject" both: feed different tokens at position 2 on the dirty cache.
    alt = rng.integers(2, 256, size=(1, 2))
    l_dirty, _, _ = fwd(tparams, cfg, alt, kdirty, vdirty, [2])
    l_clean, _, _ = fwd(tparams, cfg, alt, k2, v2, [2])
    np.testing.assert_allclose(
        np.asarray(l_dirty), np.asarray(l_clean), rtol=3e-4, atol=3e-4
    )


def test_pallas_equals_ref_both_models(tparams, dparams):
    rng = np.random.default_rng(2)
    for cfg, params in [
        (model.target_config(), tparams),
        (model.draft_config(), dparams),
    ]:
        b, s = 2, 4
        toks = rng.integers(2, 256, size=(b, s))
        k0, v0 = model.empty_cache(cfg, b)
        lr, _, _ = fwd(params, cfg, toks, k0, v0, [3, 0], use_pallas=False)
        lp, _, _ = fwd(params, cfg, toks, k0, v0, [3, 0], use_pallas=True)
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lp), rtol=3e-4, atol=3e-4)


def test_top_k_route_properties():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(7, 8)), jnp.float32)
    route = model.top_k_route(logits, 2)
    r = np.asarray(route)
    # Exactly K nonzero per row, each row sums to 1, weights positive.
    assert ((r > 0).sum(axis=1) == 2).all()
    np.testing.assert_allclose(r.sum(axis=1), 1.0, rtol=1e-6)
    # The top-1 logit is always selected.
    assert all(r[i, np.argmax(np.asarray(logits)[i])] > 0 for i in range(7))


def test_param_specs_match_arch_presets():
    """Parameter accounting agrees with the documented tiny-model size.

    Note: the MoE FFN here uses a 2-matrix relu block (w1, w2), while the
    generic rust `arch` accounting assumes 3-matrix gated FFNs for the
    paper-scale models; the tiny model's serving path never uses the
    analytic FLOP model, so only the absolute size matters here.
    """
    cfg = model.target_config()
    total = sum(int(np.prod(s)) for _, s in model.param_specs(cfg))
    d = 128
    attn = 4 * d * d
    ffn = 8 * 2 * d * 256 + d * 8  # 8 experts × (w1 + w2) + gate
    embed = 256 * d
    norms = 4 * 2 * d + d
    expected = 4 * (attn + ffn) + embed + norms
    assert total == expected, (total, expected)
    assert 2.3e6 < total < 2.5e6  # "~2.4M params" in the docs
    # Draft is much smaller (spec §3.1: cheap drafting).
    dtotal = sum(int(np.prod(s)) for _, s in model.param_specs(model.draft_config()))
    assert dtotal < 0.4 * total


def test_corpus_properties():
    data = corpus.make_corpus(100, seed=1)
    assert data.min() >= 0 and data.max() < 256
    assert (data == corpus.BOS).sum() == 100
    assert (data == corpus.EOS).sum() == 100
    # ASCII content only between markers.
    content = data[(data != corpus.BOS) & (data != corpus.EOS)]
    assert content.min() >= 32
    # Deterministic.
    np.testing.assert_array_equal(data, corpus.make_corpus(100, seed=1))


def test_training_smoke_loss_decreases():
    """A short training run must reduce loss (fast: tiny batch/steps)."""
    from compile import train

    cfg = model.draft_config()
    params = model.init_params(cfg, 9)
    m, v = train.adam_init(params)
    step = train.make_step(cfg, lr=3e-3)
    data = corpus.make_corpus(500, seed=2)
    losses = []
    for i, (x, y) in enumerate(corpus.batches(data, 8, 32, 40, seed=3)):
        params, m, v, loss = step(params, m, v, i + 1, jnp.asarray(x), jnp.asarray(y))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses[:: max(1, len(losses) // 8)]
