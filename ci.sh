#!/usr/bin/env bash
# One-command gate for this repo. Future PRs run this before merging.
#
#   ./ci.sh          # fmt + clippy + tier-1 (build + tests)
#   ./ci.sh --fast   # tier-1 only
#
# Clippy policy: correctness/suspicious/complexity/perf lints are hard
# errors; the style group stays advisory so the gate tracks real defects
# rather than idiom churn.
set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ $FAST -eq 0 ]]; then
    echo "== cargo fmt --check"
    cargo fmt --check

    echo "== cargo clippy (lib + bins, -D warnings, style advisory)"
    cargo clippy --lib --bins -- -D warnings -A clippy::style
fi

echo "== tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

echo "CI gate passed."
