#!/usr/bin/env bash
# One-command gate for this repo. Future PRs run this before merging.
#
#   ./ci.sh          # fmt + clippy + tier-1 (build + tests)
#   ./ci.sh --fast   # tier-1 only
#
# Clippy policy: correctness/suspicious/complexity/perf lints are hard
# errors; the style group stays advisory so the gate tracks real defects
# rather than idiom churn.
set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ $FAST -eq 0 ]]; then
    echo "== cargo fmt --check"
    cargo fmt --check

    echo "== cargo clippy (lib + bins, -D warnings, style advisory)"
    cargo clippy --lib --bins -- -D warnings -A clippy::style

    # Rustdoc gate: broken intra-doc links / malformed doc markup are
    # errors, so the module-map documentation can't rot. --no-deps keeps
    # the vendored stub crates out of scope.
    echo "== cargo doc (rustdoc warnings are errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
fi

# Tier-1's `cargo test` includes the library doctests (no target sets
# `doctest = false`), so the documented entry points in theory/, perfmodel/
# and control/ — including the ragged-γ helpers — are executed here, not
# just rendered by the `cargo doc` gate above. Verify with
# `cargo test --doc` if in doubt.
echo "== tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

if [[ $FAST -eq 0 ]]; then
    # Hot-path perf gate: reduced-rep micro-bench run that asserts the
    # §Perf <5% coordinator-overhead budget and the >=5x sparse-vs-dense
    # hot-path speedup, exercises the JSON emitter, and — once a full run
    # has populated BENCH_hotpath.json on this machine — compares against
    # that baseline with tolerance bands (fail >15% regression, warn >5%;
    # MOESD_SKIP_BASELINE=1 to skip on a foreign machine). Smoke runs
    # never write the tracked baseline (too noisy; and CI must not dirty
    # the checkout) — seed/refresh it with a full
    # `cargo bench --bench micro_hotpath` run.
    echo "== micro_hotpath smoke (MOESD_SMOKE=1, release bench)"
    MOESD_SMOKE=1 cargo bench --bench micro_hotpath

    # Multi-tenant serving smoke: replay the tiny bundled trace through
    # the load x admission-policy sweep and validate the per-tenant stats
    # JSON shape the operators consume.
    echo "== multitenant smoke (tiny bundled trace)"
    MOESD_SMOKE=1 cargo run --release --bin moesd -- bench multitenant --smoke
    echo "== validate results/multitenant.json shape"
    if command -v python3 >/dev/null 2>&1; then
        python3 - <<'PYEOF'
import json, sys
with open("results/multitenant.json") as f:
    doc = json.load(f)
assert doc["experiment"] == "multitenant", doc.get("experiment")
arms = doc["arms"]
assert arms, "no arms in multitenant.json"
policies = {a["policy"] for a in arms}
assert {"ar", "fifo", "class", "class+mix"} <= policies, policies
for a in arms:
    for key in ("load", "tok_s", "speedup", "slos_met", "classes"):
        assert key in a, f"arm missing {key}: {a.keys()}"
    assert len(a["classes"]) == 3, a["classes"]
    for c in a["classes"]:
        for key in ("name", "completed", "tokens", "ttft_p99",
                    "ttft_slo_attainment", "tpot_slo_attainment"):
            assert key in c, f"class missing {key}"
print(f"multitenant.json shape OK ({len(arms)} arms)")
PYEOF
    else
        # Minimal fallback without python3: the load-bearing keys exist.
        for key in '"experiment"' '"arms"' '"ttft_slo_attainment"' '"slos_met"'; do
            grep -q "$key" results/multitenant.json || {
                echo "multitenant.json missing $key"; exit 1; }
        done
        echo "multitenant.json shape OK (grep fallback)"
    fi

    # Continuous-batching smoke: replay the tiny bundled trace through
    # all four pipeline arms (lockstep / +chunked / +draft-ahead / full)
    # and validate the sweep JSON shape. The smoke path skips the
    # calibrated margin checks (they need the full 120s synthetic trace —
    # `moesd bench continuous` with no flags runs them).
    echo "== continuous smoke (tiny bundled trace)"
    MOESD_SMOKE=1 cargo run --release --bin moesd -- bench continuous --smoke
    echo "== validate results/continuous.json shape"
    if command -v python3 >/dev/null 2>&1; then
        python3 - <<'PYEOF'
import json
with open("results/continuous.json") as f:
    doc = json.load(f)
assert doc["experiment"] == "continuous", doc.get("experiment")
arms = doc["arms"]
assert arms, "no arms in continuous.json"
names = {a["arm"] for a in arms}
assert {"lockstep", "+chunked", "+draft-ahead", "full"} <= names, names
for a in arms:
    for key in ("load", "arm", "completed", "tokens", "ttft_p99",
                "tpot_mean", "goodput", "hidden_frac", "prefill_chunks"):
        assert key in a, f"arm missing {key}: {a.keys()}"
    assert a["tokens"] > 0, f"{a['arm']} committed no tokens"
    if a["arm"] == "lockstep":
        assert a["prefill_chunks"] == 0, a
    else:
        assert a["prefill_chunks"] > 0, f"{a['arm']} never chunked a prefill"
print(f"continuous.json shape OK ({len(arms)} arms)")
PYEOF
    else
        # Minimal fallback without python3: the load-bearing keys exist.
        for key in '"experiment"' '"arms"' '"hidden_frac"' '"prefill_chunks"'; do
            grep -q "$key" results/continuous.json || {
                echo "continuous.json missing $key"; exit 1; }
        done
        echo "continuous.json shape OK (grep fallback)"
    fi

    # Verify-budget smoke: one memory-bound point through the (γ, budget)
    # sweep. The smoke grid skips the replica-calibrated margin claims
    # (full `moesd bench budget` runs them) but still enforces the exact
    # budget=E off-switch identity at every point via check_shape — the
    # bench exits non-zero if any capped arm diverges bit-wise from its
    # unbudgeted twin.
    echo "== budget smoke (off-switch identity gate)"
    MOESD_SMOKE=1 cargo run --release --bin moesd -- bench budget --smoke
    echo "== validate results/budget.json shape"
    if command -v python3 >/dev/null 2>&1; then
        python3 - <<'PYEOF'
import json
with open("results/budget.json") as f:
    doc = json.load(f)
assert doc["smoke"] is True, doc.get("smoke")
assert doc["sensitivity"] > 0, doc.get("sensitivity")
points = doc["points"]
assert points, "no points in budget.json"
for p in points:
    for key in ("alpha", "k", "batch", "fabric", "devices",
                "best_off_tok_s", "best_off_gamma", "best_budgeted_tok_s",
                "best_budgeted_gamma", "best_budget", "budget_edge",
                "identity_ok"):
        assert key in p, f"point missing {key}: {sorted(p.keys())}"
    assert p["identity_ok"] is True, f"off-switch identity failed: {p}"
    assert p["best_off_tok_s"] > 0, p
    assert p["best_budgeted_tok_s"] > 0, p
    assert 1 <= p["best_budget"] < 64, f"sub-coverage budget expected: {p}"
print(f"budget.json shape OK ({len(points)} points)")
PYEOF
    else
        # Minimal fallback without python3: the load-bearing keys exist
        # and no point reported a broken off-switch identity.
        for key in '"sensitivity"' '"points"' '"budget_edge"' '"identity_ok"'; do
            grep -q "$key" results/budget.json || {
                echo "budget.json missing $key"; exit 1; }
        done
        if grep -q '"identity_ok": *false' results/budget.json; then
            echo "budget.json reports a broken off-switch identity"; exit 1
        fi
        echo "budget.json shape OK (grep fallback)"
    fi

    # Distributed coordinator overhead smoke: single-process vs the
    # pipelined loopback coordinator at B in {8,32,128}, gating the
    # <=5% per-round overhead budget at B=32 (same baseline rules as
    # micro_hotpath: smoke never writes BENCH_dist_overhead.json).
    echo "== dist_overhead smoke (MOESD_SMOKE=1, release bench)"
    MOESD_SMOKE=1 cargo bench --bench dist_overhead

    # Distributed-serving smoke: boot the coordinator/worker engine
    # (2 striped draft replicas + 2 verify ranks, in-process loopback
    # transport, pipelining on), replay a few rows of the bundled tiny
    # trace through the TCP front-end, and validate the `"dist"` fleet
    # table in the stats surface — including the PR-10 pipelining and
    # op-log compaction counters. The bit-exactness and fault-injection
    # claims live in `cargo test` (prop_distributed / fault_injection);
    # this gate pins the serve wiring end-to-end.
    DIST_PORT=7461
    echo "== distributed serve smoke (--dist-workers 2 --draft-workers 2, port $DIST_PORT)"
    cargo run --release --bin moesd -- serve --mode synthetic \
        --port "$DIST_PORT" --dist-workers 2 --draft-workers 2 --max-batch 4 &
    DIST_PID=$!
    trap 'kill "$DIST_PID" 2>/dev/null || true' EXIT
    for _ in $(seq 1 100); do
        if (exec 3<>"/dev/tcp/127.0.0.1/$DIST_PORT") 2>/dev/null; then
            exec 3>&- 3<&- || true
            break
        fi
        kill -0 "$DIST_PID" 2>/dev/null || { echo "dist serve died during startup"; exit 1; }
        sleep 0.1
    done
    if command -v python3 >/dev/null 2>&1; then
        DIST_PORT="$DIST_PORT" python3 - <<'PYEOF'
import json, os, socket
# Replay the first rows of the bundled trace: byte-tokenizer prompts of
# the recorded lengths, then pull the stats snapshot.
rows = []
with open("examples/traces/tiny_production.csv") as f:
    next(f)
    for line in f:
        t, plen, olen = line.strip().split(",")
        rows.append((int(plen), min(int(olen), 12)))
        if len(rows) == 6:
            break
assert rows, "bundled trace is empty"
s = socket.create_connection(("127.0.0.1", int(os.environ["DIST_PORT"])), timeout=60)
f = s.makefile("rw", encoding="utf-8", newline="\n")
for i, (plen, olen) in enumerate(rows):
    f.write(json.dumps({
        "id": i, "prompt": "x" * plen,
        "max_new_tokens": olen, "temperature": 0.0,
    }) + "\n")
f.flush()
done = 0
while done < len(rows):
    resp = json.loads(f.readline())
    assert "error" not in resp, resp
    assert resp["n_tokens"] > 0, resp
    done += 1
f.write(json.dumps({"stats": True}) + "\n")
f.flush()
stats = json.loads(f.readline())
s.close()
dist = stats["dist"]
workers = dist["workers"]
assert len(workers) == 4, f"want 2 draft + 2 verify ranks, got {len(workers)}"
assert [w["role"] for w in workers] == ["draft", "draft", "verify", "verify"], workers
assert [w["rank"] for w in workers] == [0, 1, 0, 1], workers
for w in workers:
    for key in ("role", "rank", "alive", "queue_depth", "ops",
                "retries", "respawns", "heartbeat"):
        assert key in w, f"worker missing {key}: {sorted(w.keys())}"
    assert w["alive"] is True, f"dead worker in a clean run: {w}"
    assert w["ops"] > 0, f"worker served no compute ops: {w}"
for key in ("retries", "respawns", "stale_discarded", "wire_errors",
            "in_flight", "pipelined", "oplog_len", "snapshots",
            "compacted_ops", "replayed_ops"):
    assert key in dist, f"dist missing {key}: {sorted(dist.keys())}"
assert dist["respawns"] == 0, f"clean loopback run respawned: {dist}"
assert dist["pipelined"] > 0, f"nothing completed in flight: {dist}"
assert dist["replayed_ops"] == 0, f"clean run replayed ops: {dist}"
print(f"dist stats shape OK ({done} requests, {len(workers)} workers)")
PYEOF
    else
        # Minimal fallback without python3: stats over /dev/tcp, check
        # the load-bearing dist keys exist.
        exec 3<>"/dev/tcp/127.0.0.1/$DIST_PORT"
        printf '{"stats": true}\n' >&3
        read -r STATS_LINE <&3
        exec 3>&- 3<&- || true
        for key in '"dist"' '"workers"' '"alive"' '"respawns"' '"stale_discarded"' \
                   '"in_flight"' '"pipelined"' '"oplog_len"' '"snapshots"'; do
            case "$STATS_LINE" in
                *"$key"*) ;;
                *) echo "dist stats missing $key"; exit 1 ;;
            esac
        done
        echo "dist stats shape OK (grep fallback)"
    fi
    kill "$DIST_PID" 2>/dev/null || true
    wait "$DIST_PID" 2>/dev/null || true
    trap - EXIT
fi

echo "CI gate passed."
