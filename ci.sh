#!/usr/bin/env bash
# One-command gate for this repo. Future PRs run this before merging.
#
#   ./ci.sh          # fmt + clippy + tier-1 (build + tests)
#   ./ci.sh --fast   # tier-1 only
#
# Clippy policy: correctness/suspicious/complexity/perf lints are hard
# errors; the style group stays advisory so the gate tracks real defects
# rather than idiom churn.
set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ $FAST -eq 0 ]]; then
    echo "== cargo fmt --check"
    cargo fmt --check

    echo "== cargo clippy (lib + bins, -D warnings, style advisory)"
    cargo clippy --lib --bins -- -D warnings -A clippy::style

    # Rustdoc gate: broken intra-doc links / malformed doc markup are
    # errors, so the module-map documentation can't rot. --no-deps keeps
    # the vendored stub crates out of scope.
    echo "== cargo doc (rustdoc warnings are errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
fi

# Tier-1's `cargo test` includes the library doctests (no target sets
# `doctest = false`), so the documented entry points in theory/, perfmodel/
# and control/ — including the ragged-γ helpers — are executed here, not
# just rendered by the `cargo doc` gate above. Verify with
# `cargo test --doc` if in doubt.
echo "== tier-1: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

if [[ $FAST -eq 0 ]]; then
    # Hot-path perf gate: reduced-rep micro-bench run that asserts the
    # §Perf <5% coordinator-overhead budget and the >=5x sparse-vs-dense
    # hot-path speedup, and exercises the JSON emitter. Smoke runs never
    # write the tracked BENCH_hotpath.json baseline (too noisy; and CI
    # must not dirty the checkout) — seed/refresh it with a full
    # `cargo bench --bench micro_hotpath` run.
    echo "== micro_hotpath smoke (MOESD_SMOKE=1, release bench)"
    MOESD_SMOKE=1 cargo bench --bench micro_hotpath
fi

echo "CI gate passed."
